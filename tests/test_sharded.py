"""Hybrid-parallel sharded EmbeddingCollection: the planner's device
assignment, sharded-vs-single-device exactness (the acceptance property),
host-precision interplay, checkpointing, and the forced-4-device mesh path."""
import os
import pathlib
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import collection as col
from repro.core.sharded import ShardedEmbeddingCollection, flat_store

REPO = pathlib.Path(__file__).resolve().parents[1]


def small_tables(dim=8, ids=16):
    return [
        col.TableConfig("big", vocab=512, dim=dim, ids_per_step=ids, cache_ratio=0.2),
        col.TableConfig("small", vocab=96, dim=dim, ids_per_step=ids, cache_ratio=0.3),
    ]


def rand_fb(tables, n, seed):
    rng = np.random.default_rng(seed)
    return col.FeatureBatch(ids={
        t.name: jnp.asarray(rng.integers(-1, t.vocab, n).astype(np.int32))
        for t in tables
    })


# --------------------------------------------------------------------------
# planner device-assignment pass
# --------------------------------------------------------------------------


def test_assign_devices_balances_expected_traffic():
    # Zipf-ish skew whose hottest rank holds < 1/S of the mass, so a near-1.0
    # balance is achievable (when one rank dominates, its share is the floor)
    counts = 1e6 / (np.arange(1000, dtype=np.float64) + 1) ** 0.8
    a = col.PlacementPlanner.assign_devices(1000, 4, counts)
    assert a.owner.shape == (1000,) and a.local.shape == (1000,)
    # every shard holds at most ceil(vocab/S) rows; together they hold all
    assert a.shard_rows.max() <= a.rows_per_shard
    assert a.shard_rows.sum() == 1000
    # locals are dense per shard: 0..rows-1
    for s in range(4):
        got = np.sort(a.local[a.owner == s])
        np.testing.assert_array_equal(got, np.arange(a.shard_rows[s]))
    # greedy LPT balances the count mass well (max/mean close to 1)
    assert a.imbalance() < 1.05, a.shard_load
    # deterministic: same inputs, same assignment
    b = col.PlacementPlanner.assign_devices(1000, 4, counts)
    np.testing.assert_array_equal(a.owner, b.owner)


def test_assign_devices_round_robin_without_counts():
    a = col.PlacementPlanner.assign_devices(10, 3, None)
    np.testing.assert_array_equal(a.owner, np.arange(10) % 3)
    np.testing.assert_array_equal(a.local, np.arange(10) // 3)


def test_assign_devices_rejects_bad_shapes():
    with pytest.raises(ValueError):
        col.PlacementPlanner.assign_devices(10, 0)
    with pytest.raises(ValueError):
        col.PlacementPlanner.assign_devices(10, 2, np.ones(7))


# --------------------------------------------------------------------------
# exactness: sharded == dense reference, every step (the paper property)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("num_shards", [1, 3, 4])
def test_sharded_lookup_matches_dense_reference_bitwise(num_shards):
    tables = small_tables()
    coll = ShardedEmbeddingCollection.create(tables, num_shards=num_shards,
                                             cache_ratio=0.2)
    rng = np.random.default_rng(1)
    counts = {t.name: rng.integers(0, 50, t.vocab) for t in tables}
    state = coll.init(jax.random.PRNGKey(0), counts=counts)
    step = jax.jit(lambda s, fb: coll.lookup(s, fb))
    for i in range(10):
        fb = rand_fb(tables, 16, seed=100 + i)
        state, addr, rows = step(state, fb)
        ref = coll.dense_reference(coll.flush(state), fb)
        for f in fb.features:
            np.testing.assert_array_equal(np.asarray(rows[f]), np.asarray(ref[f]))
        pad = np.asarray(fb.ids[f]) < 0
        assert bool((np.asarray(addr[f])[pad] == -1).all())


def test_one_shard_is_bit_identical_to_unsharded_collection():
    """mesh=1 shard must be the unsharded collection, bit for bit: same init
    draws, same table contents, same addresses-modulo-layout gathers."""
    tables = small_tables()
    ref = col.EmbeddingCollection.create(tables, cache_ratio=0.2)
    sc = ShardedEmbeddingCollection.create(tables, num_shards=1, cache_ratio=0.2)
    rng = np.random.default_rng(2)
    counts = {t.name: rng.integers(0, 50, t.vocab) for t in tables}
    st_ref = ref.init(jax.random.PRNGKey(0), counts=counts)
    st_sh = sc.init(jax.random.PRNGKey(0), counts=counts)
    # identical slow tiers (1-shard layout is the identity permutation)
    for sname in ref.cached_slabs:
        np.testing.assert_array_equal(
            np.asarray(st_ref.slabs[sname].full["weight"]),
            np.asarray(flat_store(st_sh.slabs[sname].full)["weight"]),
        )
    for i in range(6):
        fb = rand_fb(tables, 16, seed=200 + i)
        st_ref, a_ref = ref.prepare(st_ref, fb)
        st_sh, a_sh = sc.prepare(st_sh, fb)
        r_ref = ref.gather(ref.weights(st_ref), a_ref, fb)
        r_sh = sc.gather(sc.weights(st_sh), a_sh, fb)
        for f in fb.features:
            np.testing.assert_array_equal(np.asarray(a_ref[f]), np.asarray(a_sh[f]))
            np.testing.assert_array_equal(np.asarray(r_ref[f]), np.asarray(r_sh[f]))


@pytest.mark.parametrize("num_shards", [1, 4])
def test_sharded_dlrm_loss_trajectory_matches_single_device(num_shards):
    """The acceptance property: the sharded collection reproduces the
    single-device loss trajectory (fp32: bit-exact — the cache is pure data
    movement per shard and gathers read identical values)."""
    from repro.data import synth
    from repro.models.dlrm import DLRM, DLRMConfig

    base = dict(vocab_sizes=(2048, 256, 64), embed_dim=8, batch_size=16,
                cache_ratio=0.15, lr=0.2, bottom_mlp=(16, 8), top_mlp=(16,))
    spec = synth.ZipfSparseSpec(vocab_sizes=base["vocab_sizes"], n_dense=13)

    def make(s):
        return {k: jnp.asarray(v) for k, v in synth.sparse_batch(spec, 16, 0, s).items()}

    def losses(shards):
        model = DLRM(DLRMConfig(**base, model_shards=shards))
        state = model.init(jax.random.PRNGKey(0))
        step = jax.jit(model.train_step)
        out = []
        for i in range(8):
            state, m = step(state, make(i))
            out.append(float(m["loss"]))
        return out

    assert losses(0) == losses(num_shards)


def test_sharded_pipelined_trainer_bit_identical_to_serial():
    """Pipelined groups plan per shard: the group guard and future addresses
    ride the sharded plan unchanged, so depth-k grouping stays loss-bit-
    identical on a sharded collection too."""
    from repro.data import synth
    from repro.models.dlrm import DLRM, DLRMConfig
    from repro.train.trainer import PipelinedTrainer, Trainer, TrainerConfig

    cfg = DLRMConfig(vocab_sizes=(1024, 128), embed_dim=8, batch_size=16,
                     cache_ratio=0.25, lr=0.1, bottom_mlp=(16, 8), top_mlp=(16,),
                     model_shards=2)
    spec = synth.ZipfSparseSpec(vocab_sizes=cfg.vocab_sizes, n_dense=13)

    def make_batch(step):
        return {k: jnp.asarray(v) for k, v in synth.sparse_batch(spec, 16, 0, step).items()}

    model = DLRM(cfg)
    serial = Trainer(TrainerConfig(max_steps=6),
                     init_fn=lambda: model.init(jax.random.PRNGKey(0)),
                     step_fn=jax.jit(model.train_step),
                     make_batch=make_batch, flush_fn=model.flush)
    serial.run()

    model2 = DLRM(cfg)
    piped = PipelinedTrainer(
        TrainerConfig(max_steps=6, pipeline_depth=2),
        init_fn=lambda: model2.init(jax.random.PRNGKey(0)),
        plan_fn=jax.jit(model2.plan_step),
        compute_fn=jax.jit(model2.compute_step),
        apply_fn=jax.jit(model2.apply_step),
        make_batch=make_batch, flush_fn=model2.flush)
    piped.run()
    assert [h["loss"] for h in serial.history] == [h["loss"] for h in piped.history]
    # exchange telemetry recorded as exact ints
    assert isinstance(serial.history[-1]["exchange_bytes"], int)


# --------------------------------------------------------------------------
# sharded state x host_precision (satellite)
# --------------------------------------------------------------------------


def test_sharded_int8_sideband_shards_with_payload():
    tables = small_tables()
    sc = ShardedEmbeddingCollection.create(tables, num_shards=4,
                                           cache_ratio=0.2, host_precision="int8")
    state = sc.init(jax.random.PRNGKey(0))
    for sname, spec in sc.cached_slabs.items():
        store = state.slabs[sname].full
        vs = sc.rows_per_shard(spec)
        assert store.data["weight"].shape == (4, vs, spec.dim)
        assert store.data["weight"].dtype == jnp.int8
        # per-row (scale, zp) sideband travels shard-for-shard with its rows
        assert store.sideband["weight"].shape == (4, vs, 2)
        # the sharded store is a permutation of the unsharded encoding: each
        # rank's (payload, sideband) pair is the row-wise encode of its row
        flat = flat_store(store)
        a = sc.assignments[sname]
        dest = a.owner.astype(np.int64) * vs + a.local.astype(np.int64)
        dec = np.asarray(flat.decode_rows(jnp.asarray(dest, jnp.int32))["weight"])
        assert np.isfinite(dec).all() and dec.shape == (spec.vocab, spec.dim)


@pytest.mark.parametrize("codec", ["fp16", "int8"])
def test_sharded_quantized_evict_reload_payload_stable(codec):
    """Evict/reload through per-shard transmitters keeps the store invariant
    (same contract as the unsharded store, tested in test_store): untouched
    rows keep a bit-stable encoded payload across arbitrary eviction cycles,
    and lookups track the slow tier to codec noise."""
    tables = [col.TableConfig("t", vocab=256, dim=8, ids_per_step=8, cache_ratio=0.05)]
    sc = ShardedEmbeddingCollection.create(tables, num_shards=2, cache_ratio=0.05,
                                           host_precision=codec)
    state = sc.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)

    def churn(state, n):
        for _ in range(n):  # tiny cache -> constant eviction traffic
            fb = col.FeatureBatch(ids={"t": jnp.asarray(
                rng.integers(0, 256, 8).astype(np.int32))})
            state, addr = sc.prepare(state, fb)
            rows = sc.gather(sc.weights(state), addr, fb)
            ref = sc.dense_reference(sc.flush(state), fb)
            np.testing.assert_allclose(np.asarray(rows["t"]), np.asarray(ref["t"]),
                                       atol=1e-6)
        return state

    state = churn(state, 6)
    state = sc.flush(state)
    store0 = state.slabs[col.SHARED_ARENA].full
    pay0 = np.asarray(store0.data["weight"])
    side0 = np.asarray(store0.sideband["weight"]) if store0.sideband else None
    state = churn(state, 6)  # more evict/reload cycles, no row updates
    state = sc.flush(state)
    store1 = state.slabs[col.SHARED_ARENA].full
    np.testing.assert_array_equal(pay0, np.asarray(store1.data["weight"]))
    if side0 is not None:
        # payload is bit-stable; the sideband recompute drifts by float ulps
        # only (the same contract test_store pins for the unsharded path)
        np.testing.assert_allclose(side0, np.asarray(store1.sideband["weight"]),
                                   atol=1e-6)
    m = sc.metrics(state)
    assert int(m["cache_evictions"]) > 0  # the round trips actually happened


def test_sharded_one_shard_int8_bit_identical_to_unsharded():
    """S=1 with a lossy codec still bit-matches the unsharded collection:
    row-wise quantization is layout-invariant and the 1-shard permutation is
    the identity."""
    from repro.data import synth
    from repro.models.dlrm import DLRM, DLRMConfig

    base = dict(vocab_sizes=(1024, 128), embed_dim=8, batch_size=16,
                cache_ratio=0.1, lr=0.2, bottom_mlp=(16, 8), top_mlp=(16,),
                host_precision="int8")
    spec = synth.ZipfSparseSpec(vocab_sizes=base["vocab_sizes"], n_dense=13)

    def losses(shards):
        model = DLRM(DLRMConfig(**base, model_shards=shards))
        state = model.init(jax.random.PRNGKey(0))
        step = jax.jit(model.train_step)
        out = []
        for i in range(6):
            batch = {k: jnp.asarray(v)
                     for k, v in synth.sparse_batch(spec, 16, 0, i).items()}
            state, m = step(state, batch)
            out.append(float(m["loss"]))
        return out

    assert losses(0) == losses(1)


def test_sharded_int8_losses_allclose_to_unsharded():
    """Sharded lossy codecs agree with the single-device run to codec noise
    (eviction schedules differ per shard, so quantize round trips differ)."""
    from repro.data import synth
    from repro.models.dlrm import DLRM, DLRMConfig

    base = dict(vocab_sizes=(1024, 128), embed_dim=8, batch_size=16,
                cache_ratio=0.1, lr=0.2, bottom_mlp=(16, 8), top_mlp=(16,),
                host_precision="int8")
    spec = synth.ZipfSparseSpec(vocab_sizes=base["vocab_sizes"], n_dense=13)

    def losses(shards):
        model = DLRM(DLRMConfig(**base, model_shards=shards))
        state = model.init(jax.random.PRNGKey(0))
        step = jax.jit(model.train_step)
        out = []
        for i in range(8):
            batch = {k: jnp.asarray(v)
                     for k, v in synth.sparse_batch(spec, 16, 0, i).items()}
            state, m = step(state, batch)
            out.append(float(m["loss"]))
        return out

    np.testing.assert_allclose(losses(0), losses(4), atol=5e-3)


def test_sharded_int8_checkpoint_roundtrip_exact(tmp_path):
    """The encoded sharded store (payload + sideband, stacked [S, ...])
    persists and restores exactly through the checkpointer."""
    from repro.train import checkpoint as ckpt

    tables = small_tables()
    sc = ShardedEmbeddingCollection.create(tables, num_shards=4,
                                           cache_ratio=0.2, host_precision="int8")
    state = sc.init(jax.random.PRNGKey(0))
    for i in range(4):
        fb = rand_fb(tables, 16, seed=300 + i)
        state, _ = sc.prepare(state, fb)
    state = sc.flush(state)
    ckpt.save(str(tmp_path), 7, {"emb": state})
    like = jax.eval_shape(
        lambda: {"emb": sc.init(jax.random.PRNGKey(0), warm=False)}
    )
    restored, step = ckpt.restore(str(tmp_path), like)
    assert step == 7
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
        {"emb": state}, restored,
    )


# --------------------------------------------------------------------------
# structure + telemetry
# --------------------------------------------------------------------------


def test_sharded_shard_specs_structure_matches_state():
    tables = small_tables()
    for codec in ("fp32", "int8"):
        sc = ShardedEmbeddingCollection.create(tables, num_shards=4,
                                               cache_ratio=0.2, host_precision=codec)
        state = sc.init(jax.random.PRNGKey(0))
        specs = sc.shard_specs()
        assert jax.tree_util.tree_structure(state) == jax.tree_util.tree_structure(specs)


def test_exchange_telemetry_counts_valid_lanes():
    tables = [col.TableConfig("t", vocab=128, dim=8, ids_per_step=8, cache_ratio=0.3)]
    sc = ShardedEmbeddingCollection.create(tables, num_shards=2, cache_ratio=0.3)
    state = sc.init(jax.random.PRNGKey(0))
    fb = col.FeatureBatch(ids={"t": jnp.asarray([1, 2, 3, -1, -1, 5, 6, -1], jnp.int32)})
    state, _ = sc.prepare(state, fb)
    state, _ = sc.prepare(state, fb)
    m = sc.metrics(state)
    lanes = int(m["exchange_routed_lanes"][col.SHARED_ARENA])
    assert lanes == 2 * 5  # 5 valid lanes per step, cumulative
    per_lane = int(m["exchange_lane_bytes"][col.SHARED_ARENA])
    assert per_lane == 4 + 8 * 4  # id out + one dim-8 fp32 row back
    assert float(m["exchange_bytes"]) == lanes * per_lane
    from repro.core.collection import exact_metric_bytes
    assert exact_metric_bytes(m, "exchange_routed_lanes",
                              "exchange_lane_bytes") == lanes * per_lane


def test_device_budget_mode_composes_with_sharding():
    """A budget plan (DEVICE + CACHED mix) shards only the cached slabs;
    DEVICE tables replicate and the whole thing stays exact."""
    tables = [
        col.TableConfig("big", vocab=4096, dim=8, ids_per_step=16, cache_ratio=0.1),
        col.TableConfig("hot", vocab=64, dim=8, ids_per_step=16),
    ]
    sc = ShardedEmbeddingCollection.create(tables, num_shards=2, budget_bytes=80_000)
    assert sc.device_slabs and sc.cached_slabs
    state = sc.init(jax.random.PRNGKey(0))
    from repro.core.sharded import ShardedSlab
    assert isinstance(state.slabs["big"], ShardedSlab)
    assert state.slabs["hot"].weight.shape == (64, 8)  # replicated DeviceSlab
    fb = rand_fb(tables, 16, seed=4)
    state, _, rows = sc.lookup(state, fb)
    ref = sc.dense_reference(sc.flush(state), fb)
    for f in fb.features:
        np.testing.assert_array_equal(np.asarray(rows[f]), np.asarray(ref[f]))


# --------------------------------------------------------------------------
# the real mesh: forced 4 host devices in a subprocess
# --------------------------------------------------------------------------


def run_sub(code: str, n_dev: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


def test_sharded_collection_on_4_device_mesh_matches_reference():
    """Acceptance: a 4-shard collection jitted over a real (data=1, model=4)
    host mesh — state physically split one cache arena per device — produces
    the single-device reference loss trajectory."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        import repro.dist.partitioning as dist
        from repro.launch.mesh import make_hybrid_mesh
        from repro.data import synth
        from repro.models.dlrm import DLRM, DLRMConfig

        base = dict(vocab_sizes=(2048, 256), embed_dim=8, batch_size=16,
                    cache_ratio=0.15, lr=0.2, bottom_mlp=(16, 8), top_mlp=(16,))
        spec = synth.ZipfSparseSpec(vocab_sizes=base["vocab_sizes"], n_dense=13)
        make = lambda s: {k: jnp.asarray(v)
                          for k, v in synth.sparse_batch(spec, 16, 0, s).items()}

        ref = DLRM(DLRMConfig(**base))
        rs = ref.init(jax.random.PRNGKey(0))
        rstep = jax.jit(ref.train_step)
        ref_losses = []
        for i in range(6):
            rs, m = rstep(rs, make(i))
            ref_losses.append(float(m["loss"]))

        model = DLRM(DLRMConfig(**base, model_shards=4))
        state = model.init(jax.random.PRNGKey(0))
        mesh = make_hybrid_mesh(4)
        sh = lambda t: jax.tree_util.tree_map(
            lambda p: NamedSharding(mesh, p), t, is_leaf=lambda x: isinstance(x, P))
        sspecs = {"params": jax.tree_util.tree_map(lambda _: P(), state["params"]),
                  "opt": jax.tree_util.tree_map(lambda _: P(), state["opt"]),
                  "emb": model.collection.shard_specs(), "step": P()}
        bspecs = {"dense": P("data", None), "sparse": P("data", None),
                  "label": P("data")}
        state = jax.device_put(state, sh(sspecs))
        with dist.axis_rules(mesh, dist.hybrid_rules()):
            step = jax.jit(model.train_step, in_shardings=(sh(sspecs), sh(bspecs)))
            losses = []
            for i in range(6):
                state, m = step(state, make(i))
                losses.append(float(m["loss"]))
        np.testing.assert_allclose(losses, ref_losses, rtol=0, atol=0)
        w = state["emb"].slabs["__shared__"].cache.cached_rows["weight"]
        assert len(w.sharding.device_set) == 4, w.sharding
        assert float(m["exchange_bytes"]) > 0
        print("SHARDED_MESH_EXACT")
    """)
    assert "SHARDED_MESH_EXACT" in out
