"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.embedding_bag.ops import embedding_bag
from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.kernels.fm_interaction.ops import fm_interaction
from repro.kernels.fm_interaction.ref import fm_interaction_naive, fm_interaction_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


@pytest.mark.parametrize("v,d,n,s", [(64, 512, 40, 10), (128, 1024, 100, 7), (32, 256, 16, 16)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
@pytest.mark.parametrize("combiner", ["sum", "mean"])
def test_embedding_bag_sweep(v, d, n, s, dtype, combiner):
    rng = np.random.default_rng(v + n)
    table = jnp.asarray(rng.normal(size=(v, d)).astype(np.float32)).astype(dtype)
    seg = jnp.asarray(np.sort(rng.integers(0, s, n)).astype(np.int32))
    ids = jnp.asarray(rng.integers(-1, v, n).astype(np.int32))
    mb = int(np.bincount(np.asarray(seg), minlength=s).max())
    out = embedding_bag(table, ids, seg, s, combiner, max_bag=mb)
    ref = embedding_bag_ref(table, ids, seg, s, combiner)
    tol = 1e-5 if dtype == np.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=tol, atol=tol
    )


@pytest.mark.parametrize("b,f,d", [(64, 39, 10), (1000, 26, 16), (128, 8, 128), (1, 4, 4)])
def test_fm_interaction_sweep(b, f, d):
    rng = np.random.default_rng(b + f)
    v = jnp.asarray(rng.normal(size=(b, f, d)).astype(np.float32))
    out = fm_interaction(v)
    ref = fm_interaction_ref(v)
    naive = fm_interaction_naive(v)
    # fp32 reduction-order noise scales with the output magnitude
    scale = float(np.abs(np.asarray(ref)).max()) + 1.0
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-3, atol=1e-5 * scale)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(naive), rtol=1e-3, atol=1e-5 * scale)


@pytest.mark.parametrize(
    "b,hq,hkv,s,d,causal,window",
    [
        (2, 4, 2, 512, 64, True, None),
        (1, 4, 4, 512, 64, True, 128),
        (2, 8, 2, 256, 32, False, None),
        (1, 2, 1, 1024, 128, True, 256),
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, hq, hkv, s, d, causal, window, dtype):
    rng = np.random.default_rng(s + hq)
    q = jnp.asarray(rng.normal(size=(b, s, hq, d)).astype(np.float32)).astype(dtype)
    k = jnp.asarray(rng.normal(size=(b, s, hkv, d)).astype(np.float32)).astype(dtype)
    v = jnp.asarray(rng.normal(size=(b, s, hkv, d)).astype(np.float32)).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, window=window)
    ref = attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        causal, window,
    ).transpose(0, 2, 1, 3)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=tol, atol=tol
    )


def test_flash_attention_grad_matches_ref_grad():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, 256, 2, 32)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 256, 2, 32)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 256, 2, 32)).astype(np.float32))

    def lk(q_):
        return flash_attention(q_, k, v).sum()

    def lr(q_):
        return attention_ref(
            q_.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3), True, None
        ).sum()

    gk = jax.grad(lk)(q)
    gr = jax.grad(lr)(q)
    np.testing.assert_allclose(np.asarray(gk), np.asarray(gr), rtol=1e-4, atol=1e-4)


def test_nn_embedding_bag_pallas_path_matches_xla_path():
    from repro.nn.embedding_bag import embedding_bag as nn_bag

    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.normal(size=(50, 512)).astype(np.float32))
    ids = jnp.asarray(np.array([3, 7, -1, 4, 9, 9], np.int32))
    seg = jnp.asarray(np.array([0, 0, 1, 1, 2, 2], np.int32))
    a = nn_bag(table, ids, seg, 3, use_pallas=False)
    b = nn_bag(table, ids, seg, 3, use_pallas=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)
