"""Cache core: Algorithm 1 invariants + exactness against a dense oracle."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cached_embedding as ce
from repro.core import cache as cache_lib
from repro.core.policies import Policy


def make_cfg(**kw):
    kw.setdefault("vocab_sizes", (50, 30))
    kw.setdefault("dim", 8)
    kw.setdefault("ids_per_step", 12)
    kw.setdefault("cache_ratio", 0.2)
    kw.setdefault("buffer_rows", 5)
    return ce.CachedEmbeddingConfig(**kw)


def zipf_counts(vocab, seed=0):
    z = np.random.default_rng(seed).zipf(1.5, size=100_000) % vocab
    return np.bincount(z, minlength=vocab)


@pytest.fixture(scope="module")
def state_and_cfg():
    cfg = make_cfg()
    st = ce.init_state(jax.random.PRNGKey(0), cfg, counts=zipf_counts(cfg.vocab))
    return cfg, st


def test_exactness_vs_oracle_stream(state_and_cfg):
    """THE paper property: cache = pure data movement, lookups exact."""
    cfg, st = state_and_cfg
    step = jax.jit(lambda s, i: ce.embed_onehot(cfg, s, i))
    key = jax.random.PRNGKey(1)
    for _ in range(25):
        key, k = jax.random.split(key)
        ids = jax.random.randint(k, (6, 2), 0, jnp.array([50, 30])).astype(jnp.int32)
        st, slots, emb = step(st, ids)
        ref = ce.dense_reference_lookup(ce.flush_state(cfg, st), ids)
        np.testing.assert_allclose(np.asarray(emb), np.asarray(ref), rtol=0, atol=0)


def test_all_requested_rows_resident(state_and_cfg):
    cfg, st = state_and_cfg
    ids = jax.random.randint(jax.random.PRNGKey(3), (12,), 0, 50).astype(jnp.int32)
    st2, slots = ce.prepare_ids(cfg, st, ids)
    assert bool((np.asarray(slots) >= 0).all())
    # slot/row maps are mutually inverse on resident rows
    s2r = np.asarray(st2.cache.slot_to_row)
    r2s = np.asarray(st2.cache.row_to_slot)
    for slot, row in enumerate(s2r):
        if row >= 0:
            assert r2s[row] == slot
    resident_rows = s2r[s2r >= 0]
    assert len(np.unique(resident_rows)) == len(resident_rows), "duplicate cached rows"


def test_padding_gives_zero_rows(state_and_cfg):
    cfg, st = state_and_cfg
    ids = jnp.full((12,), -1, jnp.int32)
    st2, slots = ce.prepare_ids(cfg, st, ids)
    assert bool((np.asarray(slots) == -1).all())
    rows = ce.gather_slots(st2, slots)
    assert bool((np.asarray(rows) == 0).all())


def test_freq_lfu_evicts_coldest():
    """With freq-ordered rows, victims must be the largest-rank resident rows."""
    cfg = make_cfg(vocab_sizes=(40,), ids_per_step=4, cache_ratio=0.25)  # capacity 10
    st = ce.init_state(jax.random.PRNGKey(0), cfg, warm=True)  # rows 0..9 resident
    # touch 4 cold rows -> must evict ranks 9,8,7,6 (the coldest), keep 0..5
    st2, _ = ce.prepare_ids(cfg, st, jnp.array([30, 31, 32, 33], jnp.int32))
    resident = set(np.asarray(st2.cache.slot_to_row).tolist())
    assert {0, 1, 2, 3, 4, 5} <= resident
    assert {6, 7, 8, 9}.isdisjoint(resident)


def test_protected_rows_never_evicted():
    """Algorithm 1 'backlist': rows needed now survive even if coldest."""
    cfg = make_cfg(vocab_sizes=(40,), ids_per_step=8, cache_ratio=0.25)
    st = ce.init_state(jax.random.PRNGKey(0), cfg, warm=True)
    # request the two coldest resident rows + 6 new ones; the two must stay
    ids = jnp.array([8, 9, 20, 21, 22, 23, 24, 25], jnp.int32)
    st2, slots = ce.prepare_ids(cfg, st, ids)
    resident = set(np.asarray(st2.cache.slot_to_row).tolist())
    assert {8, 9, 20, 21, 22, 23, 24, 25} <= resident


def test_hit_rate_improves_with_skew(state_and_cfg):
    cfg, _ = state_and_cfg
    st = ce.init_state(jax.random.PRNGKey(0), cfg, counts=zipf_counts(cfg.vocab))
    rng = np.random.default_rng(0)
    step = jax.jit(lambda s, i: ce.embed_onehot(cfg, s, i))
    for _ in range(30):
        # zipf-distributed raw ids favour hot (low-rank) rows
        ids = (rng.zipf(1.7, size=(6, 2)) % np.array([50, 30])).astype(np.int32)
        st, _, _ = step(st, jnp.asarray(ids))
    assert float(st.cache.hit_rate()) > 0.5


def test_policies_all_run(state_and_cfg):
    for pol in Policy:
        cfg = make_cfg(policy=pol)
        st = ce.init_state(jax.random.PRNGKey(0), cfg)
        st, _, emb = ce.embed_onehot(cfg, st, jnp.zeros((6, 2), jnp.int32))
        assert bool(jnp.isfinite(emb).all())


def test_update_then_flush_roundtrip(state_and_cfg):
    cfg, st = state_and_cfg
    ids = jax.random.randint(jax.random.PRNGKey(5), (6, 2), 0, 30).astype(jnp.int32)
    st, slots, emb = ce.embed_onehot(cfg, st, ids)
    g = jnp.ones_like(st.cache.cached_rows["weight"])
    st = ce.apply_row_grads(cfg, st, g, lr=0.5)
    st_f = ce.flush_state(cfg, st)
    ref = ce.dense_reference_lookup(st_f, ids)
    _, _, emb2 = ce.embed_onehot(cfg, st_f, ids)
    np.testing.assert_allclose(np.asarray(emb2), np.asarray(ref))


def test_rowwise_adagrad_rows_travel_with_cache():
    cfg = make_cfg(rowwise_adagrad=True)
    st = ce.init_state(jax.random.PRNGKey(0), cfg)
    ids = jnp.arange(12, dtype=jnp.int32)
    st, slots = ce.prepare_ids(cfg, st, ids)
    g = jnp.ones_like(st.cache.cached_rows["weight"])
    st = ce.apply_row_grads(cfg, st, g, lr=0.1)
    assert float(st.cache.cached_rows["accum"].max()) > 0
    st_f = ce.flush_state(cfg, st)
    assert float(st_f.full["accum"].max()) > 0  # accumulator written back


def test_unique_overflow_detected():
    cfg = make_cfg(vocab_sizes=(100,), ids_per_step=16, max_unique_per_step=4, cache_ratio=0.3)
    st = ce.init_state(jax.random.PRNGKey(0), cfg)
    ids = jnp.arange(16, dtype=jnp.int32)  # 16 distinct > bound of 4
    st2, _ = ce.prepare_ids(cfg, st, ids)
    assert int(st2.cache.uniq_overflows) == 1
    st3, _ = ce.prepare_ids(cfg, st2, jnp.zeros(16, jnp.int32))  # 1 distinct: fine
    assert int(st3.cache.uniq_overflows) == 1


def test_writeback_false_keeps_full_table():
    cfg = make_cfg(writeback=False)
    st = ce.init_state(jax.random.PRNGKey(0), cfg)
    before = np.asarray(st.full["weight"]).copy()
    st2, _ = ce.prepare_ids(cfg, st, jax.random.randint(jax.random.PRNGKey(1), (12,), 0, 80).astype(jnp.int32))
    np.testing.assert_array_equal(before, np.asarray(st2.full["weight"]))


# --------------------------------------------------------------------------
# eviction_key: every Policy variant against a numpy oracle + tie order
# --------------------------------------------------------------------------


def _key_oracle(policy, slot_to_row, last_used, use_count):
    """Independent numpy statement of each policy's eviction key."""
    if policy is Policy.FREQ_LFU:
        return slot_to_row.astype(np.int64)  # static rank: larger = colder
    if policy in (Policy.LRU, Policy.UVM_ROW):
        return -last_used.astype(np.int64)  # oldest access evicts first
    if policy is Policy.RUNTIME_LFU:
        return -use_count.astype(np.int64)  # fewest uses evicts first
    raise AssertionError(policy)


@pytest.mark.parametrize(
    "policy", [Policy.FREQ_LFU, Policy.LRU, Policy.RUNTIME_LFU, Policy.UVM_ROW]
)
def test_eviction_key_matches_numpy_oracle(policy):
    rng = np.random.default_rng(0)
    slot_to_row = rng.integers(-1, 40, 24).astype(np.int32)
    last_used = rng.integers(0, 9, 24).astype(np.int32)
    use_count = rng.integers(0, 5, 24).astype(np.int32)
    got = np.asarray(
        cache_lib.eviction_key(
            policy,
            jnp.asarray(slot_to_row),
            jnp.asarray(last_used),
            jnp.asarray(use_count),
        )
    )
    np.testing.assert_array_equal(got, _key_oracle(policy, slot_to_row, last_used, use_count))


@pytest.mark.parametrize(
    "policy", [Policy.FREQ_LFU, Policy.LRU, Policy.RUNTIME_LFU, Policy.UVM_ROW]
)
def test_victim_order_deterministic_under_ties(policy):
    """plan_prepare's victim order is a STABLE descending argsort of the key:
    tied slots evict in slot order, identically across calls — every data
    rank must pick the same victims (the determinism the paper's replicated
    bookkeeping relies on)."""
    cfg = cache_lib.CacheConfig(
        vocab=40, capacity=8, ids_per_step=4, policy=policy, buffer_rows=4
    )
    st = cache_lib.init_cache(cfg, {"weight": jnp.zeros((4,), jnp.float32)})
    # fill all 8 slots with rows 0..7; uniform recency/use -> all keys tie
    # (FREQ_LFU keys differ by construction; the others are fully tied)
    full = {"weight": jnp.arange(40 * 4, dtype=jnp.float32).reshape(40, 4)}
    full, st, _ = cache_lib.prepare(cfg, full, st, jnp.arange(8, dtype=jnp.int32)[:4])
    full, st, _ = cache_lib.prepare(cfg, full, st, jnp.arange(4, 8, dtype=jnp.int32))
    plan_a = cache_lib.plan_prepare(cfg, st, jnp.asarray([20, 21, 22, 23], jnp.int32))
    plan_b = cache_lib.plan_prepare(cfg, st, jnp.asarray([20, 21, 22, 23], jnp.int32))
    np.testing.assert_array_equal(
        np.asarray(plan_a.victim_slots), np.asarray(plan_b.victim_slots)
    )
    # numpy oracle of the same stable descending order over the key
    key = np.asarray(
        cache_lib.eviction_key(policy, st.slot_to_row, st.last_used, st.use_count)
    ).astype(np.int64)
    key[np.asarray(st.slot_to_row) < 0] = np.iinfo(np.int32).max // 2  # empty first
    protected = np.isin(np.asarray(st.slot_to_row), [20, 21, 22, 23])
    key[protected] = -(np.iinfo(np.int32).max // 2)
    # stable descending == lexsort on (slot asc) within equal -key
    order = np.lexsort((np.arange(8), -key))
    np.testing.assert_array_equal(np.asarray(plan_a.victim_slots), order[:4])


def test_uvm_row_key_is_recency_not_frequency():
    """UVM_ROW (the TorchRec-UVM stand-in) must key on recency: a slot with
    huge use_count but stale last_used evicts before a fresh slot."""
    slot_to_row = jnp.asarray([0, 1], jnp.int32)
    last_used = jnp.asarray([1, 9], jnp.int32)
    use_count = jnp.asarray([100, 1], jnp.int32)
    key = np.asarray(
        cache_lib.eviction_key(Policy.UVM_ROW, slot_to_row, last_used, use_count)
    )
    assert key[0] > key[1]  # stale slot carries the larger (evict-first) key
