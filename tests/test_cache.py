"""Cache core: Algorithm 1 invariants + exactness against a dense oracle."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cached_embedding as ce
from repro.core import cache as cache_lib
from repro.core.policies import Policy


def make_cfg(**kw):
    kw.setdefault("vocab_sizes", (50, 30))
    kw.setdefault("dim", 8)
    kw.setdefault("ids_per_step", 12)
    kw.setdefault("cache_ratio", 0.2)
    kw.setdefault("buffer_rows", 5)
    return ce.CachedEmbeddingConfig(**kw)


def zipf_counts(vocab, seed=0):
    z = np.random.default_rng(seed).zipf(1.5, size=100_000) % vocab
    return np.bincount(z, minlength=vocab)


@pytest.fixture(scope="module")
def state_and_cfg():
    cfg = make_cfg()
    st = ce.init_state(jax.random.PRNGKey(0), cfg, counts=zipf_counts(cfg.vocab))
    return cfg, st


def test_exactness_vs_oracle_stream(state_and_cfg):
    """THE paper property: cache = pure data movement, lookups exact."""
    cfg, st = state_and_cfg
    step = jax.jit(lambda s, i: ce.embed_onehot(cfg, s, i))
    key = jax.random.PRNGKey(1)
    for i in range(25):
        key, k = jax.random.split(key)
        ids = jax.random.randint(k, (6, 2), 0, jnp.array([50, 30])).astype(jnp.int32)
        st, slots, emb = step(st, ids)
        ref = ce.dense_reference_lookup(ce.flush_state(cfg, st), ids)
        np.testing.assert_allclose(np.asarray(emb), np.asarray(ref), rtol=0, atol=0)


def test_all_requested_rows_resident(state_and_cfg):
    cfg, st = state_and_cfg
    ids = jax.random.randint(jax.random.PRNGKey(3), (12,), 0, 50).astype(jnp.int32)
    st2, slots = ce.prepare_ids(cfg, st, ids)
    assert bool((np.asarray(slots) >= 0).all())
    # slot/row maps are mutually inverse on resident rows
    s2r = np.asarray(st2.cache.slot_to_row)
    r2s = np.asarray(st2.cache.row_to_slot)
    for slot, row in enumerate(s2r):
        if row >= 0:
            assert r2s[row] == slot
    resident_rows = s2r[s2r >= 0]
    assert len(np.unique(resident_rows)) == len(resident_rows), "duplicate cached rows"


def test_padding_gives_zero_rows(state_and_cfg):
    cfg, st = state_and_cfg
    ids = jnp.full((12,), -1, jnp.int32)
    st2, slots = ce.prepare_ids(cfg, st, ids)
    assert bool((np.asarray(slots) == -1).all())
    rows = ce.gather_slots(st2, slots)
    assert bool((np.asarray(rows) == 0).all())


def test_freq_lfu_evicts_coldest():
    """With freq-ordered rows, victims must be the largest-rank resident rows."""
    cfg = make_cfg(vocab_sizes=(40,), ids_per_step=4, cache_ratio=0.25)  # capacity 10
    st = ce.init_state(jax.random.PRNGKey(0), cfg, warm=True)  # rows 0..9 resident
    # touch 4 cold rows -> must evict ranks 9,8,7,6 (the coldest), keep 0..5
    st2, _ = ce.prepare_ids(cfg, st, jnp.array([30, 31, 32, 33], jnp.int32))
    resident = set(np.asarray(st2.cache.slot_to_row).tolist())
    assert {0, 1, 2, 3, 4, 5} <= resident
    assert {6, 7, 8, 9}.isdisjoint(resident)


def test_protected_rows_never_evicted():
    """Algorithm 1 'backlist': rows needed now survive even if coldest."""
    cfg = make_cfg(vocab_sizes=(40,), ids_per_step=8, cache_ratio=0.25)
    st = ce.init_state(jax.random.PRNGKey(0), cfg, warm=True)
    # request the two coldest resident rows + 6 new ones; the two must stay
    ids = jnp.array([8, 9, 20, 21, 22, 23, 24, 25], jnp.int32)
    st2, slots = ce.prepare_ids(cfg, st, ids)
    resident = set(np.asarray(st2.cache.slot_to_row).tolist())
    assert {8, 9, 20, 21, 22, 23, 24, 25} <= resident


def test_hit_rate_improves_with_skew(state_and_cfg):
    cfg, _ = state_and_cfg
    st = ce.init_state(jax.random.PRNGKey(0), cfg, counts=zipf_counts(cfg.vocab))
    rng = np.random.default_rng(0)
    step = jax.jit(lambda s, i: ce.embed_onehot(cfg, s, i))
    for i in range(30):
        # zipf-distributed raw ids favour hot (low-rank) rows
        ids = (rng.zipf(1.7, size=(6, 2)) % np.array([50, 30])).astype(np.int32)
        st, _, _ = step(st, jnp.asarray(ids))
    assert float(st.cache.hit_rate()) > 0.5


def test_policies_all_run(state_and_cfg):
    for pol in Policy:
        cfg = make_cfg(policy=pol)
        st = ce.init_state(jax.random.PRNGKey(0), cfg)
        st, _, emb = ce.embed_onehot(cfg, st, jnp.zeros((6, 2), jnp.int32))
        assert bool(jnp.isfinite(emb).all())


def test_update_then_flush_roundtrip(state_and_cfg):
    cfg, st = state_and_cfg
    ids = jax.random.randint(jax.random.PRNGKey(5), (6, 2), 0, 30).astype(jnp.int32)
    st, slots, emb = ce.embed_onehot(cfg, st, ids)
    g = jnp.ones_like(st.cache.cached_rows["weight"])
    st = ce.apply_row_grads(cfg, st, g, lr=0.5)
    st_f = ce.flush_state(cfg, st)
    ref = ce.dense_reference_lookup(st_f, ids)
    _, _, emb2 = ce.embed_onehot(cfg, st_f, ids)
    np.testing.assert_allclose(np.asarray(emb2), np.asarray(ref))


def test_rowwise_adagrad_rows_travel_with_cache():
    cfg = make_cfg(rowwise_adagrad=True)
    st = ce.init_state(jax.random.PRNGKey(0), cfg)
    ids = jnp.arange(12, dtype=jnp.int32)
    st, slots = ce.prepare_ids(cfg, st, ids)
    g = jnp.ones_like(st.cache.cached_rows["weight"])
    st = ce.apply_row_grads(cfg, st, g, lr=0.1)
    assert float(st.cache.cached_rows["accum"].max()) > 0
    st_f = ce.flush_state(cfg, st)
    assert float(st_f.full["accum"].max()) > 0  # accumulator written back


def test_unique_overflow_detected():
    cfg = make_cfg(vocab_sizes=(100,), ids_per_step=16, max_unique_per_step=4, cache_ratio=0.3)
    st = ce.init_state(jax.random.PRNGKey(0), cfg)
    ids = jnp.arange(16, dtype=jnp.int32)  # 16 distinct > bound of 4
    st2, _ = ce.prepare_ids(cfg, st, ids)
    assert int(st2.cache.uniq_overflows) == 1
    st3, _ = ce.prepare_ids(cfg, st2, jnp.zeros(16, jnp.int32))  # 1 distinct: fine
    assert int(st3.cache.uniq_overflows) == 1


def test_writeback_false_keeps_full_table():
    cfg = make_cfg(writeback=False)
    st = ce.init_state(jax.random.PRNGKey(0), cfg)
    before = np.asarray(st.full["weight"]).copy()
    st2, _ = ce.prepare_ids(cfg, st, jax.random.randint(jax.random.PRNGKey(1), (12,), 0, 80).astype(jnp.int32))
    np.testing.assert_array_equal(before, np.asarray(st2.full["weight"]))
