"""Multi-device behaviour (8 fake CPU devices in a subprocess so the main
test process keeps 1 device): small-mesh dry-run lower+compile, sharded
cache-embedding step, and topology-changing (elastic) checkpoint restore."""
import json
import os
import pathlib
import subprocess
import sys
import textwrap

import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]


def run_sub(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


def test_small_mesh_lm_cell_compiles_with_collectives():
    out = run_sub("""
        import jax, json
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        import repro.dist.partitioning as dist
        from repro.launch.mesh import make_mesh
        from repro.launch import roofline
        from repro.models.lm import LMModel
        from repro.nn.transformer import TransformerConfig
        from repro.nn.layers import Dtypes
        from repro.configs.base import lm_cell
        from repro.configs.lm_common import lm_rules

        cfg = TransformerConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                                d_ff=128, vocab=256, kv_repeat=2,
                                dtypes=Dtypes(jnp.float32, jnp.float32),
                                block_q=16, block_k=16)
        mesh = make_mesh((2, 4), ("data", "model"))
        rules = lm_rules(mesh.axis_names, "train", tp_kv_param=False)  # kv=2 < tp=4
        model = LMModel(cfg)
        cell = lm_cell("tiny", "train", model, cfg, "train", 8, 64, rules)
        with dist.axis_rules(mesh, cell.rules):
            in_sh = jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, s), cell.in_specs,
                is_leaf=lambda x: isinstance(x, P))
            compiled = jax.jit(cell.step_fn, in_shardings=in_sh,
                               donate_argnums=cell.donate).lower(*cell.args).compile()
        rec = roofline.analyze_compiled(compiled)
        assert rec["flops_per_device"] > 0
        assert rec["wire_bytes_per_device"] > 0, "expected collectives on a 2x4 mesh"
        print("COLLS", sorted(rec["collectives"]))
    """)
    assert "COLLS" in out and "all-" in out


def test_sharded_cached_embedding_step_matches_single_device():
    out = run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_mesh
        from repro.core import cached_embedding as ce

        cfg = ce.CachedEmbeddingConfig(vocab_sizes=(512,), dim=16,
                                       ids_per_step=64, cache_ratio=0.25)
        st = ce.init_state(jax.random.PRNGKey(0), cfg)
        ids = jax.random.randint(jax.random.PRNGKey(1), (64,), 0, 512).astype(jnp.int32)

        # single-device reference
        st1, slots1 = ce.prepare_ids(cfg, st, ids)
        ref = ce.gather_slots(st1, slots1)

        # column-TP over a (2,4) mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        specs = ce.shard_specs(cfg, mode="column")
        sh = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), specs,
                                    is_leaf=lambda x: isinstance(x, P))
        st_sh = jax.device_put(st, sh)
        f = jax.jit(lambda s, i: ce.prepare_ids(cfg, s, i),
                    in_shardings=(sh, NamedSharding(mesh, P("data"))))
        st2, slots2 = f(st_sh, ids)
        got = ce.gather_slots(st2, slots2)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=0, atol=0)
        print("SHARDED_EXACT")
    """)
    assert "SHARDED_EXACT" in out


def test_elastic_restore_across_meshes(tmp_path):
    out = run_sub(f"""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_mesh
        from repro.train import checkpoint as C

        tree = {{"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}}
        mesh_a = make_mesh((2,), ("data",))
        sharded = jax.device_put(tree, jax.tree_util.tree_map(
            lambda _: NamedSharding(mesh_a, P("data")), tree))
        C.save(r"{tmp_path}", 11, sharded)

        # restore onto a DIFFERENT topology (8-way)
        mesh_b = make_mesh((8,), ("data",))
        like = {{"w": np.zeros((8, 8), np.float32)}}
        restored, step = C.restore(r"{tmp_path}", like)
        out = jax.device_put(restored, jax.tree_util.tree_map(
            lambda _: NamedSharding(mesh_b, P("data")), restored))
        assert step == 11
        np.testing.assert_array_equal(np.asarray(out["w"]),
                                      np.arange(64, dtype=np.float32).reshape(8, 8))
        assert len(out["w"].sharding.device_set) == 8
        print("ELASTIC_OK")
    """)
    assert "ELASTIC_OK" in out


def test_host_offload_slow_tier_compiles():
    """DESIGN.md claim: on real TPU the full table lives in host DRAM.  The
    program must compile with ``pinned_host`` placement of the slow tier
    (the CPU backend folds host memory into device, so the byte split is
    verified on TPU; well-formedness is verified here)."""
    out = run_sub("""
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P
        import repro.dist.partitioning as dist
        from repro.launch.mesh import make_mesh
        from repro.core import cached_embedding as ce

        cfg = ce.CachedEmbeddingConfig(vocab_sizes=(4096,), dim=16,
                                       ids_per_step=64, cache_ratio=0.1)
        mesh = make_mesh((2, 4), ("data", "model"))
        specs = ce.shard_specs(cfg, mode="column")
        sh = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), specs,
                                    is_leaf=lambda x: isinstance(x, P))
        # real TPUs expose pinned_host; older CPU backends only unpinned_host
        kinds = {m.kind for d in jax.devices() for m in d.addressable_memories()}
        host_kind = "pinned_host" if "pinned_host" in kinds else "unpinned_host"
        sh.full["weight"] = sh.full["weight"].with_memory_kind(host_kind)
        st = jax.eval_shape(lambda: ce.init_state(jax.random.PRNGKey(0), cfg, warm=False))
        ids = jax.ShapeDtypeStruct((64,), jax.numpy.int32)
        compiled = jax.jit(lambda s, i: ce.prepare_ids(cfg, s, i),
                           in_shardings=(sh, NamedSharding(mesh, P("data")))
                           ).lower(st, ids).compile()
        print("HOST_OFFLOAD_COMPILES")
    """)
    assert "HOST_OFFLOAD_COMPILES" in out
