"""kernels/cache_ops: bit-identity of the Pallas cache hot path.

Three layers of exactness, each against the historical route it replaces:

* reference ops (``ref.py``) vs the ``jnp.unique`` / full-capacity
  ``jnp.argsort`` oracles they displace — tie-heavy randomized trials;
* the fused ``use_pallas_plan`` planning route vs the oracle route, plan
  field by plan field, across every ``Policy`` variant, with and without
  lookahead pinning, unsharded and sharded (1 and 4 shards);
* the Pallas kernels (interpret mode, forced via
  ``REPRO_FORCE_PALLAS_CACHE_OPS``) vs the reference ops.

Plus chunk-granularity transmitter staging vs scattered-row moves.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cache as cache_lib
from repro.core import transmitter
from repro.core.collection import FeatureBatch, TableConfig
from repro.core.policies import Policy
from repro.core.sharded import ShardedEmbeddingCollection
from repro.kernels.cache_ops import kernel, ref
from repro.store.arena import ArenaStore
from repro.store.codec import get_codec
from repro.store.host_store import HostStore

_BIG = jnp.iinfo(jnp.int32).max // 2
INT_MAX = jnp.iinfo(jnp.int32).max

PLAN_FIELDS = (
    "miss_rows", "victim_slots", "victim_rows", "load_active", "evict_active",
    "slot_to_row", "row_to_slot", "last_used", "use_count", "slots",
    "hits", "misses", "evictions", "uniq_overflows",
)


# ---------------------------------------------------------------------------
# reference ops vs their oracles
# ---------------------------------------------------------------------------


def test_victim_topk_matches_argsort_under_ties():
    rng = np.random.default_rng(0)
    for trial in range(60):
        c = int(rng.integers(4, 400))
        kv = int(rng.integers(1, c + 1))
        # tie-heavy domain plus the planner's sentinel levels
        pool = np.concatenate([
            rng.integers(-4, 4, size=c),
            np.array([_BIG, -_BIG, -(_BIG // 2)]),
        ])
        key = jnp.asarray(rng.choice(pool, size=c), jnp.int32)
        want = jnp.argsort(key, descending=True)[:kv].astype(jnp.int32)
        got = ref.victim_topk(key, kv)
        assert jnp.array_equal(want, got), (trial, c, kv)


def test_victim_topk_all_equal_keys():
    # kv == capacity with every key tied: stable order = ascending index
    key = jnp.full((33,), 7, jnp.int32)
    got = ref.victim_topk(key, 33)
    assert jnp.array_equal(got, jnp.arange(33, dtype=jnp.int32))


def test_dedup_matches_unique_and_true_count():
    rng = np.random.default_rng(1)
    for trial in range(30):
        n = int(rng.integers(4, 120))
        k = int(rng.integers(1, n + 1))
        rows = rng.integers(0, 40, size=n).astype(np.int32)
        rows[rng.random(n) < 0.3] = INT_MAX  # sentinel padding lanes
        rows = jnp.asarray(rows)
        uniq, n_distinct = ref.dedup(rows, k, INT_MAX)
        want = jnp.unique(rows, size=k, fill_value=INT_MAX)
        assert jnp.array_equal(uniq, want), trial
        true = len(set(np.asarray(rows).tolist()) - {int(INT_MAX)})
        assert int(n_distinct) == true, trial


def test_compact_front_matches_stable_argsort():
    rng = np.random.default_rng(2)
    for _ in range(30):
        n = int(rng.integers(2, 64))
        mask = jnp.asarray(rng.random(n) < 0.5)
        vals = jnp.asarray(rng.integers(0, 100, size=n), jnp.int32)
        out_len = int(rng.integers(1, n + 1))
        perm = jnp.argsort(jnp.where(mask, 0, 1), stable=True)
        oracle = vals[perm][:out_len]
        got = ref.compact_front(mask, vals, out_len)
        m = min(int(jnp.sum(mask)), out_len)  # compacted prefix is the contract
        assert jnp.array_equal(got[:m], oracle[:m])
        assert jnp.all(got[m:] == -1)


# ---------------------------------------------------------------------------
# fused plan route vs oracle route
# ---------------------------------------------------------------------------


def _run_pair(policy, lookahead, steps=8, seed=3):
    rng = np.random.default_rng(seed)
    kw = dict(vocab=128, capacity=32, ids_per_step=16, buffer_rows=16,
              policy=policy)
    cfg_o = cache_lib.CacheConfig(**kw)
    cfg_p = cache_lib.CacheConfig(**kw, use_pallas_plan=True)
    ex = {"weight": jnp.zeros((8,), jnp.float32)}
    st_o = cache_lib.init_cache(cfg_o, ex)
    st_p = cache_lib.init_cache(cfg_p, ex)
    full_o = {"weight": jnp.asarray(rng.normal(size=(128, 8)), jnp.float32)}
    full_p = {"weight": full_o["weight"]}
    for step in range(steps):
        rows = jnp.asarray(rng.integers(-1, 128, size=16), jnp.int32)
        fut = None
        if lookahead:
            fut = jnp.asarray(rng.integers(-1, 128, size=16), jnp.int32)
        p_o = cache_lib.plan_prepare(cfg_o, st_o, rows, future_rows=fut)
        p_p = cache_lib.plan_prepare(cfg_p, st_p, rows, future_rows=fut)
        for f in PLAN_FIELDS:
            assert jnp.array_equal(getattr(p_o, f), getattr(p_p, f)), (
                policy, lookahead, step, f
            )
        full_o, st_o = cache_lib.apply_plan(cfg_o, full_o, st_o, p_o)
        full_p, st_p = cache_lib.apply_plan(cfg_p, full_p, st_p, p_p)
        assert jnp.array_equal(full_o["weight"], full_p["weight"])
        assert jnp.array_equal(
            st_o.cached_rows["weight"], st_p.cached_rows["weight"]
        )


@pytest.mark.parametrize("policy", list(Policy))
def test_fused_plan_bit_identical(policy):
    _run_pair(policy, lookahead=False)


@pytest.mark.parametrize("policy", list(Policy))
def test_fused_plan_bit_identical_with_lookahead(policy):
    _run_pair(policy, lookahead=True, seed=4)


def test_lookahead_pinning_identical_under_pressure():
    # capacity == unique buffer: future loads compete with pins — the branch
    # where merge order and the n_fut_load clip actually matter.
    rng = np.random.default_rng(5)
    kw = dict(vocab=64, capacity=16, ids_per_step=16, buffer_rows=8)
    cfg_o = cache_lib.CacheConfig(**kw)
    cfg_p = cache_lib.CacheConfig(**kw, use_pallas_plan=True)
    ex = {"weight": jnp.zeros((4,), jnp.float32)}
    st_o = cache_lib.init_cache(cfg_o, ex)
    st_p = cache_lib.init_cache(cfg_p, ex)
    full = {"weight": jnp.asarray(rng.normal(size=(64, 4)), jnp.float32)}
    full_o, full_p = dict(full), dict(full)
    for step in range(10):
        rows = jnp.asarray(rng.integers(-1, 64, size=16), jnp.int32)
        fut = jnp.asarray(rng.integers(-1, 64, size=16), jnp.int32)
        p_o = cache_lib.plan_prepare(cfg_o, st_o, rows, future_rows=fut)
        p_p = cache_lib.plan_prepare(cfg_p, st_p, rows, future_rows=fut)
        for f in PLAN_FIELDS:
            assert jnp.array_equal(getattr(p_o, f), getattr(p_p, f)), (step, f)
        full_o, st_o = cache_lib.apply_plan(cfg_o, full_o, st_o, p_o)
        full_p, st_p = cache_lib.apply_plan(cfg_p, full_p, st_p, p_p)
        assert jnp.array_equal(full_o["weight"], full_p["weight"])


@pytest.mark.parametrize("shards,rep_k", [(1, 0), (4, 8)])
def test_sharded_fused_plan_bit_identical(shards, rep_k):
    rng = np.random.default_rng(6)
    tables = [TableConfig("a", 192, 8, 32), TableConfig("b", 96, 8, 32)]
    c_o = ShardedEmbeddingCollection.create(
        tables, num_shards=shards, replicate_top_k=rep_k
    )
    c_p = ShardedEmbeddingCollection.create(
        tables, num_shards=shards, replicate_top_k=rep_k, use_pallas_plan=True
    )
    s_o = c_o.init(jax.random.PRNGKey(0))
    s_p = c_p.init(jax.random.PRNGKey(0))
    for step in range(4):
        fb = FeatureBatch(ids={
            "a": jnp.asarray(rng.integers(0, 192, size=32), jnp.int32),
            "b": jnp.asarray(rng.integers(0, 96, size=32), jnp.int32),
        })
        p_o = c_o.plan_prepare(s_o, fb)
        p_p = c_p.plan_prepare(s_p, fb)
        for x, y in zip(
            jax.tree_util.tree_leaves(p_o), jax.tree_util.tree_leaves(p_p)
        ):
            assert jnp.array_equal(x, y), (shards, step)
        s_o = c_o.apply_plan(s_o, p_o)
        s_p = c_p.apply_plan(s_p, p_p)
        for x, y in zip(
            jax.tree_util.tree_leaves(s_o), jax.tree_util.tree_leaves(s_p)
        ):
            assert jnp.array_equal(x, y), (shards, step)


# ---------------------------------------------------------------------------
# Pallas kernels (interpret mode) vs reference ops
# ---------------------------------------------------------------------------


def test_victim_threshold_kernel_matches_ref():
    rng = np.random.default_rng(7)
    for trial in range(10):
        c = int(rng.integers(8, 600))
        kv = int(rng.integers(1, c + 1))
        key = jnp.asarray(rng.integers(-1000, 1000, size=c), jnp.int32)
        u = ref.ordered_u32(key)
        t, n_gt = kernel.victim_threshold_pallas(u, kv, tile_rows=64,
                                                 interpret=True)
        srt = jnp.sort(u, descending=True)
        assert jnp.array_equal(t, srt[kv - 1]), trial
        assert int(n_gt) == int(jnp.sum(u > srt[kv - 1])), trial


def test_bucketize_kernel_matches_ref():
    rng = np.random.default_rng(8)
    owner = jnp.asarray(rng.integers(-1, 4, size=48), jnp.int32)
    local = jnp.asarray(
        np.where(rng.random(48) < 0.2, -1, rng.integers(0, 100, size=48)),
        jnp.int32,
    )
    want = ref.bucketize(owner, local, 4)
    got = kernel.bucketize_pallas(owner, local, 4, interpret=True)
    assert jnp.array_equal(want, got)


@pytest.mark.parametrize("codec", ["fp16", "int8"])
def test_gather_decode_kernel_matches_ref(codec):
    rng = np.random.default_rng(9)
    ar = ArenaStore.create(
        {"w": jnp.asarray(rng.normal(size=(32, 8)), jnp.float32)}, 8, codec
    )
    slots = jnp.asarray([-1, 0, 5, 7, 8, 15, 31, 40, -3, 2], jnp.int32)
    c = get_codec(codec)
    # jit both: the production context (FMA selection agrees under jit)
    want = jax.jit(
        lambda h, t, s, sl: ref.arena_gather(h, t, s, sl, c.decode, jnp.float32)
    )(ar.head["w"], ar.tail["w"], ar.sideband.get("w"), slots)
    got = jax.jit(
        lambda h, t, s, sl: kernel.gather_decode_pallas(
            h, t, s, sl, codec, jnp.float32, interpret=True
        )
    )(ar.head["w"], ar.tail["w"], ar.sideband.get("w"), slots)
    assert jnp.array_equal(want, got)


def test_forced_pallas_route_full_plan():
    """REPRO_FORCE_PALLAS_CACHE_OPS=1 (the CI interpret-mode smoke) must keep
    the whole fused plan + int8 arena gather bit-identical.  Run in a
    subprocess: the flag is read at trace time and this process has traces
    cached without it."""
    prog = textwrap.dedent("""
        import os
        os.environ["REPRO_FORCE_PALLAS_CACHE_OPS"] = "1"
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import cache as cache_lib
        from repro.kernels.cache_ops import ops
        assert ops.kernels_enabled()
        rng = np.random.default_rng(10)
        kw = dict(vocab=96, capacity=24, ids_per_step=12, buffer_rows=8,
                  arena_precision="int8")
        cfg_o = cache_lib.CacheConfig(**kw)
        cfg_p = cache_lib.CacheConfig(**kw, use_pallas_plan=True)
        ex = {"weight": jnp.zeros((8,), jnp.float32)}
        st_o = cache_lib.init_cache(cfg_o, ex)
        st_p = cache_lib.init_cache(cfg_p, ex)
        full_o = {"weight": jnp.asarray(rng.normal(size=(96, 8)), jnp.float32)}
        full_p = {"weight": full_o["weight"]}
        for step in range(4):
            rows = jnp.asarray(rng.integers(-1, 96, size=12), jnp.int32)
            p_o = cache_lib.plan_prepare(cfg_o, st_o, rows)
            p_p = cache_lib.plan_prepare(cfg_p, st_p, rows)
            assert jnp.array_equal(p_o.victim_slots, p_p.victim_slots), step
            assert jnp.array_equal(p_o.miss_rows, p_p.miss_rows), step
            full_o, st_o = cache_lib.apply_plan(cfg_o, full_o, st_o, p_o)
            full_p, st_p = cache_lib.apply_plan(cfg_p, full_p, st_p, p_p)
            assert jnp.array_equal(full_o["weight"], full_p["weight"]), step
            ga = st_o.cached_rows.gather_slots(jnp.arange(24, dtype=jnp.int32))
            gb = st_p.cached_rows.gather_slots(jnp.arange(24, dtype=jnp.int32))
            assert jnp.array_equal(ga["weight"], gb["weight"]), step
        print("OK")
    """)
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr
    assert "OK" in out.stdout


# ---------------------------------------------------------------------------
# chunk-granularity staging
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scr,dcr", [(8, 0), (0, 8), (8, 8), (16, 4), (5, 3)])
def test_chunked_move_bit_identical(scr, dcr):
    rng = np.random.default_rng(11)
    src = {"w": jnp.asarray(rng.normal(size=(64, 4)), jnp.float32)}
    dst = {"w": jnp.asarray(rng.normal(size=(32, 4)), jnp.float32)}
    si = jnp.asarray(rng.integers(-1, 64, size=24), jnp.int32)
    di = jnp.asarray(rng.permutation(32)[:24], jnp.int32)
    ac = jnp.asarray(rng.integers(0, 2, size=24), bool)
    base = transmitter.move_rows(src, dict(dst), si, di, ac, buffer_rows=8)
    got = transmitter.move_rows(src, dict(dst), si, di, ac, buffer_rows=8,
                                src_chunk_rows=scr, dst_chunk_rows=dcr)
    assert jnp.array_equal(base["w"], got["w"])


@pytest.mark.parametrize("codec", ["fp32", "fp16", "int8"])
def test_chunked_move_hoststore_bit_identical(codec):
    rng = np.random.default_rng(12)
    hs = HostStore.create(
        {"w": jnp.asarray(rng.normal(size=(64, 4)), jnp.float32)}, codec
    )
    dst = {"w": jnp.asarray(rng.normal(size=(32, 4)), jnp.float32)}
    si = jnp.asarray(rng.integers(-1, 64, size=24), jnp.int32)
    di = jnp.asarray(rng.permutation(32)[:24], jnp.int32)
    ac = jnp.asarray(rng.integers(0, 2, size=24), bool)
    # encoded source chunked
    base = transmitter.move_rows(hs, dict(dst), si, di, ac, buffer_rows=8)
    got = transmitter.move_rows(hs, dict(dst), si, di, ac, buffer_rows=8,
                                src_chunk_rows=8)
    assert jnp.array_equal(base["w"], got["w"])
    # encoded destination chunked (RMW writeback)
    src = {"w": jnp.asarray(rng.normal(size=(32, 4)), jnp.float32)}
    di2 = jnp.asarray(rng.permutation(64)[:24], jnp.int32)
    a = transmitter.move_rows(src, hs, di, di2, ac, buffer_rows=8)
    b = transmitter.move_rows(src, hs, di, di2, ac, buffer_rows=8,
                              dst_chunk_rows=8)
    for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        assert jnp.array_equal(x, y)


def test_chunked_cache_pipeline_bit_identical():
    """chunk_rows threads through apply_plan/flush/warmup unchanged."""
    rng = np.random.default_rng(13)
    kw = dict(vocab=128, capacity=32, ids_per_step=16, buffer_rows=16)
    cfg_o = cache_lib.CacheConfig(**kw)
    cfg_c = cache_lib.CacheConfig(**kw, chunk_rows=8, use_pallas_plan=True)
    ex = {"weight": jnp.zeros((8,), jnp.float32)}
    st_o = cache_lib.init_cache(cfg_o, ex)
    st_c = cache_lib.init_cache(cfg_c, ex)
    full_o = {"weight": jnp.asarray(rng.normal(size=(128, 8)), jnp.float32)}
    full_c = {"weight": full_o["weight"]}
    full_o, st_o = cache_lib.warmup(cfg_o, full_o, st_o)
    full_c, st_c = cache_lib.warmup(cfg_c, full_c, st_c)
    for _ in range(4):
        rows = jnp.asarray(rng.integers(-1, 128, size=16), jnp.int32)
        full_o, st_o, sl_o = cache_lib.prepare(cfg_o, full_o, st_o, rows)
        full_c, st_c, sl_c = cache_lib.prepare(cfg_c, full_c, st_c, rows)
        assert jnp.array_equal(sl_o, sl_c)
        assert jnp.array_equal(full_o["weight"], full_c["weight"])
    full_o, st_o = cache_lib.flush(cfg_o, full_o, st_o)
    full_c, st_c = cache_lib.flush(cfg_c, full_c, st_c)
    assert jnp.array_equal(full_o["weight"], full_c["weight"])
    assert jnp.array_equal(
        st_o.cached_rows["weight"], st_c.cached_rows["weight"]
    )
