"""HLO collective parser + roofline-term arithmetic."""
import numpy as np

from repro.launch import roofline as R

HLO = """
HloModule test
  %ag = bf16[16,1024]{1,0} all-gather(bf16[1,1024] %x), replica_groups=[16,16]<=[256], dimensions={0}
  %ar = f32[512]{0} all-reduce(f32[512] %y), replica_groups={{0,1,2,3}}, to_apply=%add
  %rs = f32[64,32]{1,0} reduce-scatter(f32[1024,32] %z), replica_groups=[16,16]<=[256], dimensions={0}
  %a2a = bf16[8,64]{1,0} all-to-all(bf16[8,64] %w), replica_groups=[32,8]<=[256]
  %cp = f32[128]{0} collective-permute(f32[128] %v), source_target_pairs={{0,1}}
  %agd = (f32[4], f32[4]) all-gather-start(f32[1] %q), replica_groups={{0,1,2,3}}
  %agd2 = f32[4] all-gather-done(%agd)
"""


def test_parse_collectives():
    stats = {c.op: c for c in R.parse_collectives(HLO)}
    assert stats["all-gather"].count == 2  # ag + ag-start (done skipped)
    ag = stats["all-gather"]
    # first all-gather: result 16*1024*2 bytes, group 16 -> wire = rb*15/16
    assert ag.result_bytes == 16 * 1024 * 2 + 2 * 4 * 4  # incl the tuple start op
    ar = stats["all-reduce"]
    assert ar.result_bytes == 512 * 4
    assert np.isclose(ar.wire_bytes, 2 * 512 * 4 * 3 / 4)
    rs = stats["reduce-scatter"]
    assert rs.result_bytes == 64 * 32 * 4
    assert np.isclose(rs.wire_bytes, 64 * 32 * 4 * 15)
    assert stats["all-to-all"].count == 1
    assert stats["collective-permute"].wire_bytes == 128 * 4


def test_roofline_terms():
    t = R.roofline_terms(197e12, 819e9, 50e9)  # exactly 1s / 1s / 1s
    assert np.isclose(t["compute_s"], 1.0) and np.isclose(t["memory_s"], 1.0)
    assert np.isclose(t["collective_s"], 1.0)
    t2 = R.roofline_terms(197e12 * 0.5, 819e9, 0.0)
    assert t2["dominant"] == "memory_s"
    assert np.isclose(t2["roofline_fraction"], 0.5)


def test_group_size_formats():
    assert R._group_size("replica_groups=[4,64]<=[256]") == 64
    assert R._group_size("replica_groups={{0,1,2,3,4,5,6,7}}") == 8
