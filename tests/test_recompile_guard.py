"""Recompile guard: each trainer entry point compiles exactly ONCE.

A silent retrace (weak-type drift, shape wobble, static-arg churn) costs a
full XLA compile per step and — worse — serializes the pipelined trainer's
overlap while losses stay correct.  These tests run the production wiring
(including the ``donate_argnums`` launch/train.py uses) under a
trace-counting harness: the wrapped Python body executes once per jit
compilation, so its call count IS the compile count.
"""
import jax
import jax.numpy as jnp

from repro.data import synth
from repro.models.dlrm import DLRM, DLRMConfig
from repro.train.trainer import PipelinedTrainer, Trainer, TrainerConfig

CFG = DLRMConfig(
    vocab_sizes=(512, 128), n_dense=13, embed_dim=8, batch_size=16,
    cache_ratio=0.25, lr=0.1, bottom_mlp=(16, 8), top_mlp=(16,),
)


def _make_batch_fn(cfg):
    spec = synth.ZipfSparseSpec(vocab_sizes=cfg.vocab_sizes, n_dense=cfg.n_dense)

    def make_batch(step):
        return {
            k: jnp.asarray(v)
            for k, v in synth.sparse_batch(spec, cfg.batch_size, 0, step).items()
        }

    return make_batch


def _counting(fn):
    """Trace-counting wrapper: the body runs once per jit COMPILATION (cached
    executions never re-enter Python)."""
    counts = {"n": 0}

    def wrapper(*args, **kwargs):
        counts["n"] += 1
        return fn(*args, **kwargs)

    return wrapper, counts


def test_serial_trainer_compiles_once_over_six_steps():
    model = DLRM(CFG)
    step, n = _counting(model.train_step)
    trainer = Trainer(
        TrainerConfig(max_steps=6),
        init_fn=lambda: model.init(jax.random.PRNGKey(0)),
        step_fn=jax.jit(step, donate_argnums=(0,)),
        make_batch=_make_batch_fn(CFG),
    )
    trainer.run()
    assert len(trainer.history) == 6
    assert n["n"] == 1, f"train_step traced {n['n']}x over 6 steps (retrace!)"


def test_pipelined_trainer_depth3_each_stage_compiles_once():
    model = DLRM(CFG)
    plan, n_plan = _counting(model.plan_step)
    compute, n_compute = _counting(model.compute_step)
    apply_, n_apply = _counting(model.apply_step)
    trainer = PipelinedTrainer(
        TrainerConfig(max_steps=6, pipeline_depth=3),
        init_fn=lambda: model.init(jax.random.PRNGKey(0)),
        plan_fn=jax.jit(plan),
        compute_fn=jax.jit(compute, donate_argnums=(0,)),
        apply_fn=jax.jit(apply_, donate_argnums=(0,)),
        make_batch=_make_batch_fn(CFG),
    )
    trainer.run()
    assert len(trainer.history) == 6
    for name, n in (("plan", n_plan), ("compute", n_compute), ("apply", n_apply)):
        assert n["n"] == 1, (
            f"{name}_step traced {n['n']}x over 6 steps / 2 groups (retrace!)"
        )


def test_donated_state_stays_trainable_and_matches_undonated():
    """Donation is an aliasing hint, not a semantics change: the loss
    trajectory with donate_argnums must equal the undonated one."""
    mk = _make_batch_fn(CFG)

    def run(donate):
        model = DLRM(CFG)
        kw = dict(donate_argnums=(0,)) if donate else {}
        t = Trainer(
            TrainerConfig(max_steps=4),
            init_fn=lambda: model.init(jax.random.PRNGKey(0)),
            step_fn=jax.jit(model.train_step, **kw),
            make_batch=mk,
        )
        t.run()
        return [h["loss"] for h in t.history]

    assert run(donate=True) == run(donate=False)
