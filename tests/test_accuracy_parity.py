"""The paper's central claim (Figs. 5/6): training THROUGH the cache matches
uncached training.  Our cache is exact data movement, so the parity is
bitwise (up to float reduction order), much stronger than the paper's <0.01
AUROC delta."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cached_embedding as ce
from repro.data import synth
from repro.models.common import auc_proxy, bce_with_logits
from repro.models.dlrm import DLRM, DLRMConfig


def train_losses(cache_ratio, steps=15, seed=0):
    cfg = DLRMConfig(
        vocab_sizes=(512, 256, 128), embed_dim=16, batch_size=32,
        cache_ratio=cache_ratio, lr=0.5, bottom_mlp=(32, 16), top_mlp=(32,),
    )
    model = DLRM(cfg)
    state = model.init(jax.random.PRNGKey(seed))
    spec = synth.ZipfSparseSpec(vocab_sizes=cfg.vocab_sizes, n_dense=13)
    step_fn = jax.jit(model.train_step)
    losses, aucs = [], []
    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in synth.sparse_batch(spec, 32, seed, i).items()}
        state, m = step_fn(state, batch)
        losses.append(float(m["loss"]))
        aucs.append(float(m["auc"]))
    return np.asarray(losses), np.asarray(aucs)


def test_cache_ratio_does_not_change_training():
    """Loss curves identical across cache ratios (incl. 100% = effectively
    uncached): the software cache is invisible to optimization."""
    base_losses, base_auc = train_losses(cache_ratio=1.0)
    for ratio in (0.25, 0.5):
        losses, _ = train_losses(cache_ratio=ratio)
        np.testing.assert_allclose(losses, base_losses, rtol=1e-5, atol=1e-6)


def test_auroc_parity_within_paper_tolerance():
    _, auc_full = train_losses(cache_ratio=1.0, steps=20)
    _, auc_small = train_losses(cache_ratio=0.25, steps=20)
    assert abs(auc_full[-1] - auc_small[-1]) < 0.01  # the paper's bound
