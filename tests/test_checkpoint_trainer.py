"""Fault tolerance: atomic checkpoints, exact resume, straggler detection."""
import json
import pathlib
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import synth
from repro.models.dlrm import DLRM, DLRMConfig
from repro.train import checkpoint as C
from repro.train.trainer import StragglerDetector, Trainer, TrainerConfig


def small_dlrm():
    cfg = DLRMConfig(vocab_sizes=(64, 32, 128), embed_dim=8, batch_size=16,
                     cache_ratio=0.3, lr=0.1, bottom_mlp=(16, 8), top_mlp=(16,))
    return DLRM(cfg), cfg


def make_batch_fn(cfg, seed=0):
    spec = synth.ZipfSparseSpec(vocab_sizes=cfg.vocab_sizes, n_dense=13)

    def make_batch(step):
        b = synth.sparse_batch(spec, cfg.batch_size, seed, step)
        return {k: jnp.asarray(v) for k, v in b.items()}

    return make_batch


# --------------------------------------------------------------------------
# checkpoint primitives
# --------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.float32(2.5)}}
    C.save(tmp_path, 7, tree)
    like = jax.tree_util.tree_map(np.asarray, tree)
    restored, step = C.restore(tmp_path, like)
    assert step == 7
    np.testing.assert_array_equal(restored["a"], np.arange(6).reshape(2, 3))
    assert float(restored["b"]["c"]) == 2.5


def test_checkpoint_gc_and_latest(tmp_path):
    tree = {"x": jnp.zeros(2)}
    for s in (1, 2, 3, 4):
        C.save(tmp_path, s, tree, keep=2)
    assert C.latest_step(tmp_path) == 4
    kept = sorted(d.name for d in tmp_path.glob("step_*"))
    assert kept == ["step_000000003", "step_000000004"]


def test_crash_mid_save_leaves_previous_intact(tmp_path):
    tree = {"x": jnp.arange(3)}
    C.save(tmp_path, 1, tree)
    # simulate a crash: garbage tmp dir + stale LATEST is fine
    (tmp_path / "step_000000002.tmp").mkdir()
    (tmp_path / "step_000000002.tmp" / "0000.npy").write_bytes(b"garbage")
    restored, step = C.restore(tmp_path, {"x": np.zeros(3, np.int32)})
    assert step == 1


def test_latest_survives_missing_marker(tmp_path):
    tree = {"x": jnp.arange(3)}
    C.save(tmp_path, 5, tree)
    (tmp_path / "LATEST").unlink()
    assert C.latest_step(tmp_path) == 5


def test_async_checkpointer(tmp_path):
    ck = C.Checkpointer(tmp_path)
    ck.save_async(3, {"x": jnp.ones(4)})
    ck.wait()
    restored, step = ck.restore_latest({"x": np.zeros(4, np.float32)})
    assert step == 3 and restored["x"].sum() == 4


# --------------------------------------------------------------------------
# trainer: exact resume == uninterrupted run (checkpoint/restart correctness)
# --------------------------------------------------------------------------


def _run(model, cfg, tmp, steps, ckpt_every=2, interrupt_at=None):
    trainer = Trainer(
        TrainerConfig(max_steps=interrupt_at or steps, ckpt_dir=str(tmp),
                      ckpt_every=ckpt_every, log_every=100),
        init_fn=lambda: model.init(jax.random.PRNGKey(0)),
        step_fn=jax.jit(model.train_step),
        make_batch=make_batch_fn(cfg),
        flush_fn=model.flush,
    )
    state = trainer.run()
    return trainer, state


def test_resume_reproduces_uninterrupted_run(tmp_path):
    model, cfg = small_dlrm()
    # uninterrupted 6 steps
    t_full, s_full = _run(model, cfg, tmp_path / "a", steps=6)
    # interrupted at 4 (ckpt every 2), then resumed to 6
    _run(model, cfg, tmp_path / "b", steps=6, interrupt_at=4)
    model2, _ = small_dlrm()
    t_res, s_res = _run(model2, cfg, tmp_path / "b", steps=6)
    # resumed losses for steps 4..5 match the uninterrupted run exactly
    full_tail = [r["loss"] for r in t_full.history if r["step"] >= 4]
    res_tail = [r["loss"] for r in t_res.history]
    np.testing.assert_allclose(res_tail, full_tail, rtol=1e-6)


def test_loss_decreases(tmp_path):
    model, cfg = small_dlrm()
    trainer, _ = _run(model, cfg, tmp_path, steps=30, ckpt_every=1000)
    first = np.mean([r["loss"] for r in trainer.history[:5]])
    last = np.mean([r["loss"] for r in trainer.history[-5:]])
    assert last < first


def test_straggler_detector():
    det = StragglerDetector(factor=3.0, warmup=3)
    for _ in range(10):
        assert not det.observe(0.1)
    assert det.observe(1.0)  # 10x the EWMA -> straggler
    assert det.flagged == 1
    assert not det.observe(0.1)  # mean not poisoned


def test_trainer_raises_on_uniq_overflow(tmp_path):
    cfg = DLRMConfig(vocab_sizes=(64, 32, 128), embed_dim=8, batch_size=16,
                     cache_ratio=0.9, lr=0.1, bottom_mlp=(16, 8), top_mlp=(16,),
                     max_unique_per_step=2)  # absurdly small bound -> overflow
    model = DLRM(cfg)
    trainer = Trainer(
        TrainerConfig(max_steps=2, ckpt_dir=None),
        init_fn=lambda: model.init(jax.random.PRNGKey(0)),
        step_fn=jax.jit(model.train_step),
        make_batch=make_batch_fn(cfg),
    )
    with pytest.raises(RuntimeError, match="overflow"):
        trainer.run()
