"""Hypothesis property tests for the system's core invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")  # optional dep; CI installs it
from hypothesis import given, settings, strategies as st

from repro.core import cached_embedding as ce
from repro.core import freq
from repro.core.policies import Policy
from repro.kernels.fm_interaction.ref import fm_interaction_naive, fm_interaction_ref
from repro.nn.indexing import take_rows

SETTINGS = dict(max_examples=25, deadline=None)


@given(
    ids=st.lists(st.integers(min_value=0, max_value=39), min_size=1, max_size=30),
    policy=st.sampled_from(list(Policy)),
    inverse_protect=st.booleans(),
)
@settings(**SETTINGS)
def test_cache_lookup_exact_for_any_stream(ids, policy, inverse_protect):
    """Invariant: for ANY id stream, policy, and backlist implementation
    (paper isin vs inverse-map scatter), cached lookup == dense."""
    cfg = ce.CachedEmbeddingConfig(
        vocab_sizes=(40,), dim=4, ids_per_step=6, cache_ratio=0.25,
        buffer_rows=3, policy=policy, protect_via_inverse=inverse_protect,
    )
    state = ce.init_state(jax.random.PRNGKey(0), cfg)
    chunks = [ids[i : i + 6] for i in range(0, len(ids), 6)]
    for chunk in chunks:
        arr = np.full((6,), -1, np.int32)
        arr[: len(chunk)] = chunk
        state, slots = ce.prepare_ids(cfg, state, jnp.asarray(arr))
        got = ce.gather_slots(state, slots)
        flushed = ce.flush_state(cfg, state)
        rows = flushed.idx_map[jnp.maximum(jnp.asarray(arr), 0)]
        want = np.where(
            (arr >= 0)[:, None], np.asarray(flushed.full["weight"])[np.asarray(rows)], 0
        )
        np.testing.assert_allclose(np.asarray(got), want, rtol=0, atol=0)


@given(counts=st.lists(st.integers(min_value=0, max_value=1000), min_size=2, max_size=64))
@settings(**SETTINGS)
def test_freq_maps_are_inverse_permutations(counts):
    stats = freq.build_freq_stats(np.asarray(counts))
    n = len(counts)
    np.testing.assert_array_equal(np.sort(stats.idx_map), np.arange(n))
    np.testing.assert_array_equal(stats.idx_map[stats.inv_map], np.arange(n))
    # ranking is by descending count
    ranked = np.asarray(counts)[stats.inv_map]
    assert (np.diff(ranked) <= 0).all()


@given(
    b=st.integers(1, 8), f=st.integers(2, 12), d=st.integers(1, 16),
    seed=st.integers(0, 2**16),
)
@settings(**SETTINGS)
def test_fm_sum_square_trick_equals_naive(b, f, d, seed):
    v = jnp.asarray(np.random.default_rng(seed).normal(size=(b, f, d)).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(fm_interaction_ref(v)), np.asarray(fm_interaction_naive(v)),
        rtol=5e-4, atol=5e-4,
    )


@given(
    n=st.integers(1, 20),
    idx=st.lists(st.integers(min_value=-3, max_value=25), min_size=1, max_size=16),
)
@settings(**SETTINGS)
def test_take_rows_negative_is_zero(n, idx):
    table = jnp.asarray(np.random.default_rng(0).normal(size=(n, 3)).astype(np.float32))
    out = np.asarray(take_rows(table, jnp.asarray(idx)))
    for lane, i in enumerate(idx):
        if 0 <= i < n:
            np.testing.assert_allclose(out[lane], np.asarray(table)[i])
        else:
            np.testing.assert_array_equal(out[lane], 0)


@given(
    seed=st.integers(0, 2**16),
    buffer_rows=st.integers(1, 16),
    k=st.integers(1, 12),
)
@settings(**SETTINGS)
def test_transmitter_any_buffer_size(seed, buffer_rows, k):
    from repro.core import transmitter

    rng = np.random.default_rng(seed)
    src = {"w": jnp.asarray(rng.normal(size=(30, 3)).astype(np.float32))}
    dst = {"w": jnp.zeros((15, 3))}
    src_idx = rng.integers(-1, 30, k).astype(np.int32)
    dst_idx = rng.permutation(15)[:k].astype(np.int32)
    active = src_idx >= 0
    out = transmitter.move_rows(
        src, dst, jnp.asarray(src_idx), jnp.asarray(dst_idx), jnp.asarray(active),
        buffer_rows=buffer_rows,
    )
    ref = np.zeros((15, 3), np.float32)
    for s_, d_, a_ in zip(src_idx, dst_idx, active):
        if a_:
            ref[d_] = np.asarray(src["w"])[s_]
    np.testing.assert_allclose(np.asarray(out["w"]), ref)
