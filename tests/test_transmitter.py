"""Bounded-buffer transmitter: chunked == single-shot; masking correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import transmitter


@pytest.mark.parametrize("buffer_rows", [1, 3, 7, 64])
def test_chunked_equals_single_shot(buffer_rows):
    rng = np.random.default_rng(0)
    src = {"w": jnp.asarray(rng.normal(size=(20, 4)).astype(np.float32)),
           "a": jnp.asarray(rng.normal(size=(20,)).astype(np.float32))}
    dst = {"w": jnp.zeros((10, 4)), "a": jnp.zeros((10,))}
    src_idx = jnp.asarray([3, 5, -1, 7, 0, 19, 2, -1], jnp.int32)
    dst_idx = jnp.asarray([0, 1, 2, 3, 4, 5, 6, 7], jnp.int32)
    active = src_idx >= 0
    out = transmitter.move_rows(src, dst, src_idx, dst_idx, active, buffer_rows=buffer_rows)
    ref_w = np.zeros((10, 4), np.float32)
    ref_a = np.zeros((10,), np.float32)
    for s, d in zip(np.asarray(src_idx), np.asarray(dst_idx)):
        if s >= 0:
            ref_w[d] = np.asarray(src["w"])[s]
            ref_a[d] = np.asarray(src["a"])[s]
    np.testing.assert_allclose(np.asarray(out["w"]), ref_w)
    np.testing.assert_allclose(np.asarray(out["a"]), ref_a)


def test_inactive_lanes_do_not_touch_dst():
    src = {"w": jnp.ones((4, 2))}
    dst = {"w": jnp.full((4, 2), 7.0)}
    out = transmitter.move_rows(
        src, dst,
        jnp.asarray([0, 1], jnp.int32), jnp.asarray([0, 1], jnp.int32),
        jnp.asarray([False, False]),
        buffer_rows=2,
    )
    np.testing.assert_array_equal(np.asarray(out["w"]), np.full((4, 2), 7.0))


def test_num_rounds():
    assert transmitter.num_rounds(10, 3) == 4
    assert transmitter.num_rounds(9, 3) == 3
    assert transmitter.num_rounds(1, 64) == 1
