"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (and mirrors them into
results/bench.csv).  Usage: ``PYTHONPATH=src python -m benchmarks.run``
(optionally ``--only fig9``).
"""
from __future__ import annotations

import argparse
import pathlib
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="substring filter on module.function (e.g. cache_ops)")
    ap.add_argument("--skip-scaling", action="store_true",
                    help="skip the multi-process scaling benchmark")
    ap.add_argument("--strict", action="store_true",
                    help="re-raise benchmark failures (CI smoke mode)")
    args = ap.parse_args()

    from benchmarks import bench_cache_ops, bench_figures, bench_scaling
    from benchmarks.common import Table

    fns = list(bench_figures.ALL) + list(bench_cache_ops.ALL)
    if not args.skip_scaling:
        fns += list(bench_scaling.ALL)

    t = Table()
    print("name,us_per_call,derived")
    for fn in fns:
        if args.only and args.only not in f"{fn.__module__}.{fn.__name__}":
            continue
        try:
            fn(t)
        except Exception as e:  # keep the harness running; report the failure
            if args.strict:
                raise
            t.add(f"{fn.__name__}/ERROR", 0.0, f"{type(e).__name__}: {e}")
    out = pathlib.Path(__file__).resolve().parents[1] / "results" / "bench.csv"
    out.parent.mkdir(exist_ok=True)
    out.write_text("name,us_per_call,derived\n" + "\n".join(
        f"{n},{u:.1f},{d}" for n, u, d in t.rows) + "\n")


if __name__ == "__main__":
    main()
