"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (and mirrors them into
results/bench.csv).  Usage: ``PYTHONPATH=src python -m benchmarks.run``
(optionally ``--only fig9``).

``--json PATH`` additionally APPENDS a machine-readable record — per-bench
medians, git sha, timestamp, smoke flag — to a JSON list at PATH, so runs
accumulate into a perf trajectory (e.g. ``BENCH_PR3.json`` checked in per
PR; regressions become a diff, not an anecdote).
"""
from __future__ import annotations

import argparse
import datetime
import json
import pathlib
import subprocess


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, cwd=pathlib.Path(__file__).parent,
        ).stdout.strip() or "unknown"
    except OSError:
        return "unknown"


def append_json_record(path: pathlib.Path, rows, smoke: bool) -> None:
    """Append one result record to the JSON list at ``path`` (created if
    missing; a corrupt/non-list file is replaced rather than crashing the
    bench run)."""
    record = {
        "git_sha": _git_sha(),
        "timestamp": datetime.datetime.now(datetime.timezone.utc).isoformat(),
        "smoke": smoke,
        "results": {n: {"us_per_call": round(u, 1), "derived": d} for n, u, d in rows},
    }
    history = []
    if path.exists():
        try:
            loaded = json.loads(path.read_text())
            if isinstance(loaded, list):
                history = loaded
        except (json.JSONDecodeError, OSError):
            pass
    history.append(record)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(history, indent=2) + "\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="substring filter on module.function (e.g. cache_ops)")
    ap.add_argument("--skip-scaling", action="store_true",
                    help="skip the multi-process scaling benchmark")
    ap.add_argument("--strict", action="store_true",
                    help="re-raise benchmark failures (CI smoke mode)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="append a machine-readable result record (per-bench "
                         "medians + git sha + timestamp) to a JSON list file")
    args = ap.parse_args()

    from benchmarks import bench_cache_ops, bench_drift, bench_figures, bench_scaling
    from benchmarks.common import SMOKE, Table

    fns = list(bench_figures.ALL) + list(bench_cache_ops.ALL) + list(bench_drift.ALL)
    if not args.skip_scaling:
        fns += list(bench_scaling.ALL)

    t = Table()
    print("name,us_per_call,derived")
    for fn in fns:
        if args.only and args.only not in f"{fn.__module__}.{fn.__name__}":
            continue
        try:
            fn(t)
        except Exception as e:  # keep the harness running; report the failure
            if args.strict:
                raise
            t.add(f"{fn.__name__}/ERROR", 0.0, f"{type(e).__name__}: {e}")
    out = pathlib.Path(__file__).resolve().parents[1] / "results" / "bench.csv"
    out.parent.mkdir(exist_ok=True)
    out.write_text("name,us_per_call,derived\n" + "\n".join(
        f"{n},{u:.1f},{d}" for n, u, d in t.rows) + "\n")
    if args.json:
        append_json_record(pathlib.Path(args.json), t.rows, SMOKE)


if __name__ == "__main__":
    main()
