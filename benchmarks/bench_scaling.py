"""Figs. 13/14 — multi-device scaling (1..8 fake CPU devices, subprocess so
the parent keeps a single device).  Measures the hybrid-parallel DLRM train
step: column-TP embedding + DP dense, the paper's §4.4 layout."""
from __future__ import annotations

import os
import pathlib
import subprocess
import sys
import textwrap

from benchmarks.common import Table

_CHILD = """
import time
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.mesh import make_mesh
from repro.data import synth
from repro.models.dlrm import DLRM, DLRMConfig
import repro.dist.partitioning as dist

n_dev = {n_dev}
cfg = DLRMConfig(vocab_sizes=(65536, 32768, 16384, 16384), embed_dim=32,
                 batch_size=2048, cache_ratio=0.1, lr=0.5,
                 bottom_mlp=(64, 32), top_mlp=(64,))
model = DLRM(cfg)
state = model.init(jax.random.PRNGKey(0))
spec = synth.ZipfSparseSpec(vocab_sizes=cfg.vocab_sizes, n_dense=13)

if n_dev == 1:
    step = jax.jit(model.train_step)
    rules = {{}}
    mesh = None
else:
    mesh = make_mesh((n_dev // 2 if n_dev > 2 else 1, 2) if n_dev > 2 else (1, n_dev),
                     ("data", "model"))
    especs = model.collection.shard_specs(mode="column")
    sh = lambda s: jax.tree_util.tree_map(lambda p: NamedSharding(mesh, p), s,
                                          is_leaf=lambda x: isinstance(x, P))
    state_specs = {{
        "params": jax.tree_util.tree_map(lambda _: P(), state["params"]),
        "opt": jax.tree_util.tree_map(lambda _: P(), state["opt"]),
        "emb": especs, "step": P(),
    }}
    bspecs = {{"dense": P("data", None), "sparse": P("data", None), "label": P("data")}}
    rules = {{"batch": ("data",)}}
    with dist.axis_rules(mesh, rules):
        step = jax.jit(model.train_step, in_shardings=(sh(state_specs), sh(bspecs)))
    state = jax.device_put(state, sh(state_specs))

batches = [{{k: jnp.asarray(v) for k, v in synth.sparse_batch(spec, 2048, 0, i).items()}}
           for i in range(6)]
with dist.axis_rules(mesh, rules) if mesh else __import__("contextlib").nullcontext():
    state, m = step(state, batches[0])  # compile + warm
    jax.block_until_ready(m["loss"])
    t0 = time.perf_counter()
    for b in batches[1:]:
        state, m = step(state, b)
    jax.block_until_ready(m["loss"])
sec = (time.perf_counter() - t0) / (len(batches) - 1)
print(f"RESULT {{sec*1e6:.1f}} {{2048/sec:.0f}}")
"""


def bench_scaling(t: Table):
    repo = pathlib.Path(__file__).resolve().parents[1]
    for n_dev in (1, 2, 4, 8):
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
        env["PYTHONPATH"] = str(repo / "src")
        out = subprocess.run(
            [sys.executable, "-c", _CHILD.format(n_dev=n_dev)],
            capture_output=True, text=True, env=env, timeout=600,
        )
        line = [l for l in out.stdout.splitlines() if l.startswith("RESULT")]
        if not line:
            t.add(f"fig13/scaling_dev{n_dev}", 0.0, f"FAILED: {out.stderr[-200:]}")
            continue
        us, sps = line[0].split()[1:3]
        t.add(f"fig13/scaling_dev{n_dev}", float(us), f"samples_per_s={sps} (host-emulated devices)")


ALL = [bench_scaling]
