"""Figs. 13/14 — multi-device scaling (1..8 fake CPU devices, subprocess so
the parent keeps a single device).  Measures the hybrid-parallel DLRM train
step — the paper's §4.4 layout, now with the sharded EmbeddingCollection:
every device on the ``model`` axis owns its own cache arena + HostStore
slice, ids bucketize to their owner and rows return through the combined
address gather.  Besides step time the child reports the id+row all-to-all
exchange bytes per step (exact, from the collection's routed-lane counters)
so ``--json`` runs (BENCH_PR4.json) record both per device count."""
from __future__ import annotations

import os
import pathlib
import subprocess
import sys

from benchmarks.common import SMOKE, Table

_CHILD = """
import time
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.collection import exact_metric_bytes
from repro.launch.mesh import make_hybrid_mesh
from repro.data import synth
from repro.models.dlrm import DLRM, DLRMConfig
import repro.dist.partitioning as dist

n_dev = {n_dev}
batch = {batch}
cfg = DLRMConfig(vocab_sizes={vocabs}, embed_dim=32,
                 batch_size=batch, cache_ratio=0.1, lr=0.5,
                 bottom_mlp=(64, 32), top_mlp=(64,),
                 model_shards=(n_dev if n_dev > 1 else 0))
model = DLRM(cfg)
state = model.init(jax.random.PRNGKey(0))
spec = synth.ZipfSparseSpec(vocab_sizes=cfg.vocab_sizes, n_dense=13)

if n_dev == 1:
    step = jax.jit(model.train_step)
    rules = {{}}
    mesh = None
else:
    # every device is a model shard; the data axis is 1 (the embedding
    # exchange is what this figure scales — dense stays replicated)
    mesh = make_hybrid_mesh(n_dev)
    especs = model.collection.shard_specs()
    sh = lambda s: jax.tree_util.tree_map(lambda p: NamedSharding(mesh, p), s,
                                          is_leaf=lambda x: isinstance(x, P))
    state_specs = {{
        "params": jax.tree_util.tree_map(lambda _: P(), state["params"]),
        "opt": jax.tree_util.tree_map(lambda _: P(), state["opt"]),
        "emb": especs, "step": P(),
    }}
    bspecs = {{"dense": P("data", None), "sparse": P("data", None), "label": P("data")}}
    rules = dist.hybrid_rules()
    with dist.axis_rules(mesh, rules):
        step = jax.jit(model.train_step, in_shardings=(sh(state_specs), sh(bspecs)))
    state = jax.device_put(state, sh(state_specs))

batches = [{{k: jnp.asarray(v) for k, v in synth.sparse_batch(spec, batch, 0, i).items()}}
           for i in range(6)]
with dist.axis_rules(mesh, rules) if mesh else __import__("contextlib").nullcontext():
    state, m = step(state, batches[0])  # compile + warm
    jax.block_until_ready(m["loss"])
    x0 = exact_metric_bytes(m, "exchange_routed_lanes", "exchange_lane_bytes") or 0
    t0 = time.perf_counter()
    for b in batches[1:]:
        state, m = step(state, b)
    jax.block_until_ready(m["loss"])
sec = (time.perf_counter() - t0) / (len(batches) - 1)
x1 = exact_metric_bytes(m, "exchange_routed_lanes", "exchange_lane_bytes") or 0
xchg = (x1 - x0) / (len(batches) - 1)
imb = float(m.get("shard_imbalance", 1.0))
print(f"RESULT {{sec*1e6:.1f}} {{batch/sec:.0f}} {{xchg:.0f}} {{imb:.2f}}")
"""


def bench_scaling(t: Table):
    repo = pathlib.Path(__file__).resolve().parents[1]
    if SMOKE:
        devs, vocabs, batch = (1, 2), (4096, 2048, 1024, 1024), 256
    else:
        devs, vocabs, batch = (1, 2, 4, 8), (65536, 32768, 16384, 16384), 2048
    for n_dev in devs:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
        env["PYTHONPATH"] = str(repo / "src")
        out = subprocess.run(
            [sys.executable, "-c",
             _CHILD.format(n_dev=n_dev, batch=batch, vocabs=vocabs)],
            capture_output=True, text=True, env=env, timeout=600,
        )
        line = [l for l in out.stdout.splitlines() if l.startswith("RESULT")]
        if not line:
            t.add(f"fig13/scaling_dev{n_dev}", 0.0, f"FAILED: {out.stderr[-200:]}")
            continue
        us, sps, xchg, imb = line[0].split()[1:5]
        t.add(
            f"fig13/scaling_dev{n_dev}", float(us),
            f"samples_per_s={sps} exchange_bytes_per_step={xchg} "
            f"shard_imbalance={imb} (host-emulated devices; exchange counts "
            f"the full id+row payload, expected cross-device fraction "
            f"{(n_dev - 1) / max(n_dev, 1):.2f})",
        )


ALL = [bench_scaling]
