"""Figs. 13/14 — multi-device scaling (1..8 fake CPU devices, subprocess so
the parent keeps a single device).  Measures the hybrid-parallel DLRM train
step — the paper's §4.4 layout with the sharded EmbeddingCollection: every
device on the ``model`` axis owns its own cache arena + HostStore slice, ids
dedup + bucketize to their owner and rows return through the combined
address gather, with the K hottest ranks served from a replicated arena that
never enters the exchange.  Besides step time the child reports the
exchange payload per step SPLIT into its id-leg and row-leg (exact, from the
collection's routed-lane counters), the per-shard routed-lane histogram, the
LIVE traffic imbalance, and the final loss (fp32 exchange + replication keep
it bit-identical to the single-device run) so ``--json`` runs
(BENCH_PR7.json) record the whole scaling picture per device count."""
from __future__ import annotations

import os
import pathlib
import subprocess
import sys

from benchmarks.common import SMOKE, Table

_CHILD = """
import time
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.collection import exact_metric_bytes
from repro.core.refresh import RefreshConfig
from repro.launch.mesh import make_hybrid_mesh
from repro.data import synth
from repro.models.dlrm import DLRM, DLRMConfig
import repro.dist.partitioning as dist

n_dev = {n_dev}
batch = {batch}
cfg = DLRMConfig(vocab_sizes={vocabs}, embed_dim=32,
                 batch_size=batch, cache_ratio=0.1, lr=0.5,
                 bottom_mlp=(64, 32), top_mlp=(64,),
                 model_shards=(n_dev if n_dev > 1 else 0),
                 replicate_top_k=({rep_k} if n_dev > 1 else 0),
                 exchange_codec="{xcodec}",
                 max_routed_per_shard={mrps})
model = DLRM(cfg)
state = model.init(jax.random.PRNGKey(0))
spec = synth.ZipfSparseSpec(vocab_sizes=cfg.vocab_sizes, n_dense=13)

if n_dev == 1:
    step = jax.jit(model.train_step)
    rules = {{}}
    mesh = None
else:
    # every device is a model shard; the data axis is 1 (the embedding
    # exchange is what this figure scales).  The BATCH still shards over the
    # model axis too: dense params replicate but dense COMPUTE splits, so no
    # per-device term stays proportional to the full batch (loss is reduced
    # with a mean, so the split is bit-identical -- tested).
    mesh = make_hybrid_mesh(n_dev)
    especs = model.collection.shard_specs()
    sh = lambda s: jax.tree_util.tree_map(lambda p: NamedSharding(mesh, p), s,
                                          is_leaf=lambda x: isinstance(x, P))
    state_specs = {{
        "params": jax.tree_util.tree_map(lambda _: P(), state["params"]),
        "opt": jax.tree_util.tree_map(lambda _: P(), state["opt"]),
        "emb": especs, "step": P(),
    }}
    bspecs = {{"dense": P(("data", "model"), None),
               "sparse": P(("data", "model"), None),
               "label": P(("data", "model"))}}
    rules = dist.hybrid_rules()
    with dist.axis_rules(mesh, rules):
        step = jax.jit(model.train_step, in_shardings=(sh(state_specs), sh(bspecs)))
    state = jax.device_put(state, sh(state_specs))

batches = [{{k: jnp.asarray(v) for k, v in synth.sparse_batch(spec, batch, 0, i).items()}}
           for i in range(6)]
moves = 0
with dist.axis_rules(mesh, rules) if mesh else __import__("contextlib").nullcontext():
    state, m = step(state, batches[0])  # compile + warm
    jax.block_until_ready(m["loss"])
    if n_dev > 1:
        # traffic-aware re-homing off the live decayed counters (front c):
        # one pass between warm-up and the timed window
        emb, report = model.collection.refresh(
            state["emb"], RefreshConfig(max_swaps=0, rebalance_threshold=1.05)
        )
        # the host-side surgery drops the mesh placement; re-shard before
        # stepping (same re-shard a restart would do)
        state = jax.device_put(dict(state, emb=emb), sh(state_specs))
        moves = sum(report.rebalance_moves.values())
    x0 = exact_metric_bytes(m, "exchange_routed_lanes", "exchange_lane_bytes") or 0
    i0 = exact_metric_bytes(m, "exchange_routed_lanes", "exchange_id_lane_bytes") or 0
    r0 = exact_metric_bytes(m, "exchange_routed_lanes", "exchange_row_lane_bytes") or 0
    h0 = np.asarray(m.get("exchange_per_shard_lanes", np.zeros(1)))
    t0 = time.perf_counter()
    for b in batches[1:]:
        state, m = step(state, b)
    jax.block_until_ready(m["loss"])
sec = (time.perf_counter() - t0) / (len(batches) - 1)
n = len(batches) - 1
x1 = exact_metric_bytes(m, "exchange_routed_lanes", "exchange_lane_bytes") or 0
i1 = exact_metric_bytes(m, "exchange_routed_lanes", "exchange_id_lane_bytes") or 0
r1 = exact_metric_bytes(m, "exchange_routed_lanes", "exchange_row_lane_bytes") or 0
h1 = np.asarray(m.get("exchange_per_shard_lanes", np.zeros(1)))
hist = ",".join(str(int(v)) for v in (h1 - h0))
imb = float(m.get("shard_imbalance", 1.0))
loss = float(jax.device_get(m["loss"]))
# the bounded plan width must never have dropped a lane (exactness guard —
# the same counter the trainer asserts on)
assert int(jax.device_get(m.get("uniq_overflows", 0))) == 0, "lane overflow"
# Host-emulated devices SERIALIZE on this runner: measured wall time is
# S*(replicated work) + (sum of per-shard work), where a real S-device mesh
# runs the shards concurrently -- its step time is the per-device critical
# path, wall/S.  Report both: samples/s from the wall clock (honest for this
# box) and the parallel projection batch/(wall/S) (what the same program
# costs when the devices are real).
proj = batch / (sec / max(n_dev, 1))
print(f"RESULT {{sec*1e6:.1f}} {{batch/sec:.0f}} {{proj:.0f}} {{(x1-x0)/n:.0f}} "
      f"{{(i1-i0)/n:.0f}} {{(r1-r0)/n:.0f}} {{imb:.2f}} {{moves}} {{loss:.6f}} {{hist}}")
"""


def bench_scaling(t: Table):
    repo = pathlib.Path(__file__).resolve().parents[1]
    if SMOKE:
        devs, vocabs, batch, rep_k = (1, 2), (4096, 2048, 1024, 1024), 256, 256
    else:
        devs, vocabs, batch, rep_k = (1, 2, 4, 8), (65536, 32768, 16384, 16384), 2048, 2048
    lanes = batch * len(vocabs)  # one shared arena slab -> dedup width
    for n_dev in devs:
        # bounded per-shard plan width at 4+ shards: 2x the balanced share
        # (rebalance keeps traffic near-even; overflow asserts in the child).
        # Below 4 shards the bound would be >= the full width — leave it off.
        mrps = 2 * lanes // n_dev if n_dev >= 4 else 0
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
        env["PYTHONPATH"] = str(repo / "src")
        out = subprocess.run(
            [sys.executable, "-c",
             _CHILD.format(n_dev=n_dev, batch=batch, vocabs=vocabs,
                           rep_k=rep_k, xcodec="fp32", mrps=mrps)],
            capture_output=True, text=True, env=env, timeout=600,
        )
        line = [l for l in out.stdout.splitlines() if l.startswith("RESULT")]
        if not line:
            t.add(f"fig13/scaling_dev{n_dev}", 0.0, f"FAILED: {out.stderr[-200:]}")
            continue
        us, sps, proj, xchg, idb, rowb, imb, moves, loss, hist = line[0].split()[1:11]
        t.add(
            f"fig13/scaling_dev{n_dev}", float(us),
            f"samples_per_s={sps} samples_per_s_parallel_projected={proj} "
            f"exchange_bytes_per_step={xchg} "
            f"id_leg_bytes_per_step={idb} row_leg_bytes_per_step={rowb} "
            f"shard_imbalance={imb} rebalance_moves={moves} loss={loss} "
            f"routed_lanes_per_shard={hist} (host-emulated devices serialize "
            f"on one core, so wall-clock pays S x the replicated prologue; "
            f"the projection wall/{n_dev} is the per-device critical path a "
            f"real {n_dev}-device mesh runs concurrently.  Dedup'd exchange, "
            f"top-{rep_k if n_dev > 1 else 0} ranks replicated, fp32 "
            f"row-leg; expected cross-device fraction "
            f"{(n_dev - 1) / max(n_dev, 1):.2f})",
        )


ALL = [bench_scaling]
