"""Hit-rate collapse and recovery under hot-set drift.

The paper's frequency module is static: counts are collected once before
training and the FREQ_LFU rank never changes.  This benchmark streams a
``DriftingZipfSpec`` workload — same skew, but the hot set rotates to a
disjoint id range every ``drift_every`` steps — through one cached table and
tracks the per-step (windowed) hit rate:

  * ``drift/no_refresh``: after the first phase change the stale ranking
    keeps thrash-evicting the new hot rows (they sit at cold ranks, so
    FREQ_LFU victimizes them first) and the hit rate stays collapsed;
  * ``drift/refresh``: the adaptive engine (online decayed counters +
    bounded incremental re-ranking every ``refresh_every`` steps) promotes
    the new hot rows across the capacity boundary and the hit rate recovers.

Both runs consume the identical stream from identical init.  ``derived``
records the pre-drift rate, the post-drift steady-state of each mode, and
the refresh pass cost; the JSON harness (``--json BENCH_PR5.json``) makes
the collapse-vs-recovery gap a tracked number.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import SMOKE, Table
from repro.core import collection as col
from repro.core.refresh import RefreshConfig
from repro.data import synth


def _steady(rates, lo, hi):
    window = [r for r in rates[lo:hi] if r is not None]
    return float(np.mean(window)) if window else 0.0


def bench_drift(t: Table):
    if SMOKE:
        vocab, dim, batch = 20_000, 8, 512
        drift_every, ratio, refresh_every, max_swaps = 40, 0.04, 2, 512
    else:
        vocab, dim, batch = 400_000, 32, 8192
        drift_every, ratio, refresh_every, max_swaps = 150, 0.02, 5, 4096
    spec = synth.DriftingZipfSpec(
        base=synth.ZipfSparseSpec(vocab_sizes=(vocab,)), drift_every=drift_every
    )
    steps = 3 * drift_every  # phase 0 matches the collected counts; 1-2 drift
    # tracker decay matched to the drift timescale: a newly-hot row must
    # outweigh the OLD hot set's decayed mass before a refresh promotes it,
    # so a half-life ~ a fraction of the phase length recovers within a phase
    table = col.TableConfig("items", vocab, dim, ids_per_step=batch,
                            cache_ratio=ratio,
                            freq_half_life=max(drift_every // 8, 1))

    # static frequency stats from a phase-0 scan (the paper's pre-training
    # collection) — honestly stale after the first phase change.
    cnt = np.zeros((vocab,), np.int64)
    for s in range(drift_every):
        b = synth.drifting_sparse_batch(spec, batch, 0, s)
        np.add.at(cnt, b["sparse"].reshape(-1).astype(np.int64), 1)
    counts = {"items": cnt}

    def make_fb(s):
        b = synth.drifting_sparse_batch(spec, batch, 0, s)
        return col.FeatureBatch.from_onehot(("items",), jnp.asarray(b["sparse"]))

    def run(with_refresh: bool):
        coll = col.EmbeddingCollection.create([table], cache_ratio=ratio)
        state = coll.init(jax.random.PRNGKey(0), counts=counts)
        prep = jax.jit(lambda st, fb: coll.prepare(st, fb))
        (sname,) = coll.cached_slabs
        rates, step_times, refresh_times = [], [], []
        ph = pm = 0
        for s in range(steps):
            fb = make_fb(s)
            t0 = time.perf_counter()
            state, _ = prep(state, fb)
            c = state.slabs[sname].cache
            h, m = int(jax.device_get(c.hits)), int(jax.device_get(c.misses))
            step_times.append(time.perf_counter() - t0)
            dh, dm = h - ph, m - pm
            ph, pm = h, m
            rates.append(dh / (dh + dm) if dh + dm else None)
            if with_refresh and (s + 1) % refresh_every == 0:
                t0 = time.perf_counter()
                # min_gain: a cold row must lead by a margin of decayed
                # mass — suppresses boundary churn (near-tied rows swapping,
                # and re-faulting, every pass) once the ranking converges
                state, _ = coll.refresh(
                    state, RefreshConfig(max_swaps=max_swaps, min_gain=0.25)
                )
                refresh_times.append(time.perf_counter() - t0)
        report = coll.metrics(state)
        return rates, step_times, refresh_times, report

    rates_no, times_no, _, _ = run(with_refresh=False)
    rates_rf, times_rf, rtimes, report = run(with_refresh=True)

    # pre-drift steady state (end of phase 0) and post-drift steady states
    # (the back half of the final phase, after recovery had time to happen)
    pre = _steady(rates_no, drift_every - drift_every // 3, drift_every)
    post_no = _steady(rates_no, steps - drift_every // 2, steps)
    post_rf = _steady(rates_rf, steps - drift_every // 2, steps)
    trough = min(r for r in rates_rf[drift_every:] if r is not None)
    med = lambda x: sorted(x)[len(x) // 2]
    swaps = int(jax.device_get(report["refresh_swaps"]))
    moved = int(jax.device_get(report["refresh_rows_moved"]))

    t.add("drift/no_refresh", med(times_no) * 1e6,
          f"hit_pre={pre:.3f} hit_post={post_no:.3f} (stale FREQ_LFU rank)")
    t.add("drift/refresh", med(times_rf) * 1e6,
          f"hit_post={post_rf:.3f} trough={trough:.3f} "
          f"recovered={post_rf - post_no:+.3f} swaps={swaps} "
          f"rows_moved={moved} refresh_ms={med(rtimes) * 1e3:.1f}")


ALL = (bench_drift,)
