"""Cache-bookkeeping overhead (the paper's claim: 'cache-related operations
... introduce very little overhead'): prepare_ids cost vs the raw lookup, and
transmitter cost vs buffer size."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Table, timeit
from repro.core import cached_embedding as ce


def bench_cache_overhead(t: Table):
    vocab, dim, n_ids = 1_000_000, 64, 16384
    cfg = ce.CachedEmbeddingConfig(vocab_sizes=(vocab,), dim=dim, ids_per_step=n_ids,
                                   cache_ratio=0.05)
    st = ce.init_state(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    ids = jnp.asarray((rng.zipf(1.4, n_ids) % vocab).astype(np.int32))

    prep = jax.jit(lambda s, i: ce.prepare_ids(cfg, s, i))
    st, slots = prep(st, ids)  # warm
    sec_prep = timeit(lambda: prep(st, ids))

    gather = jax.jit(lambda s, sl: ce.gather_slots(s, sl))
    sec_gather = timeit(lambda: gather(st, slots))

    dense = jax.jit(lambda w, i: jnp.take(w, i, axis=0))
    sec_dense = timeit(lambda: dense(st.full["weight"], ids))

    t.add("cacheops/prepare_ids", sec_prep * 1e6,
          f"vs_dense_lookup={sec_prep/sec_dense:.2f}x; gather={sec_gather*1e6:.0f}us")

    for buf in (1024, 8192, 65536):
        cfg_b = ce.CachedEmbeddingConfig(vocab_sizes=(vocab,), dim=dim,
                                         ids_per_step=n_ids, cache_ratio=0.05,
                                         buffer_rows=buf)
        st_b = ce.init_state(jax.random.PRNGKey(0), cfg_b, warm=False)
        prep_b = jax.jit(lambda s, i: ce.prepare_ids(cfg_b, s, i))
        st_b, _ = prep_b(st_b, ids)
        sec_b = timeit(lambda: prep_b(st_b, ids))
        t.add(f"cacheops/buffer_rows_{buf}", sec_b * 1e6,
              f"rounds={-(-cfg_b.unique_size//buf)}")


ALL = [bench_cache_overhead]
