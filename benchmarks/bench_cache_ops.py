"""Cache-bookkeeping overhead (the paper's claim: 'cache-related operations
... introduce very little overhead'): prepare_ids cost vs the raw lookup,
transmitter cost vs buffer size, and the collection-level comparison —
planner-driven mixed placement (DEVICE + per-table caches) vs the paper's
single shared arena."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Table, timeit
from repro.core import cached_embedding as ce
from repro.core import collection as col


def bench_cache_overhead(t: Table):
    vocab, dim, n_ids = 1_000_000, 64, 16384
    cfg = ce.CachedEmbeddingConfig(vocab_sizes=(vocab,), dim=dim, ids_per_step=n_ids,
                                   cache_ratio=0.05)
    st = ce.init_state(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    ids = jnp.asarray((rng.zipf(1.4, n_ids) % vocab).astype(np.int32))

    prep = jax.jit(lambda s, i: ce.prepare_ids(cfg, s, i))
    st, slots = prep(st, ids)  # warm
    sec_prep = timeit(lambda: prep(st, ids))

    gather = jax.jit(lambda s, sl: ce.gather_slots(s, sl))
    sec_gather = timeit(lambda: gather(st, slots))

    dense = jax.jit(lambda w, i: jnp.take(w, i, axis=0))
    sec_dense = timeit(lambda: dense(st.full["weight"], ids))

    t.add("cacheops/prepare_ids", sec_prep * 1e6,
          f"vs_dense_lookup={sec_prep/sec_dense:.2f}x; gather={sec_gather*1e6:.0f}us")

    for buf in (1024, 8192, 65536):
        cfg_b = ce.CachedEmbeddingConfig(vocab_sizes=(vocab,), dim=dim,
                                         ids_per_step=n_ids, cache_ratio=0.05,
                                         buffer_rows=buf)
        st_b = ce.init_state(jax.random.PRNGKey(0), cfg_b, warm=False)
        prep_b = jax.jit(lambda s, i: ce.prepare_ids(cfg_b, s, i))
        st_b, _ = prep_b(st_b, ids)
        sec_b = timeit(lambda: prep_b(st_b, ids))
        t.add(f"cacheops/buffer_rows_{buf}", sec_b * 1e6,
              f"rounds={-(-cfg_b.unique_size//buf)}")


def bench_collection_placement(t: Table):
    """Mixed placement vs single arena: DEVICE tables skip Algorithm 1
    entirely, so the prepare+gather path gets cheaper as the planner promotes
    more tables — the planner's whole value proposition, measured."""
    dim, batch = 64, 16384
    vocabs = {"huge": 1_000_000, "mid": 100_000, "small": 20_000, "tiny": 4_096}
    tables = [
        col.TableConfig(n, v, dim, ids_per_step=batch, cache_ratio=0.05)
        for n, v in vocabs.items()
    ]
    rng = np.random.default_rng(0)
    fb = col.FeatureBatch(ids={
        n: jnp.asarray((rng.zipf(1.4, batch) % v).astype(np.int32))
        for n, v in vocabs.items()
    })

    def run(coll, tag):
        state = coll.init(jax.random.PRNGKey(0))

        def step(state, fb):
            state, addr = coll.prepare(state, fb)
            rows = coll.gather(coll.weights(state), addr, fb)
            return state, rows

        stepj = jax.jit(step)
        state, _ = stepj(state, fb)  # warm
        sec = timeit(lambda: stepj(state, fb))
        dev = coll.device_bytes()["device_total"]
        t.add(f"cacheops/collection_{tag}", sec * 1e6,
              f"device_bytes={dev/1e6:.1f}MB plan={coll.plan.summary()}")

    run(col.EmbeddingCollection.create(tables, cache_ratio=0.05), "single_arena")
    budget = int(120e6)  # promotes small+tiny+mid, caches huge
    run(col.EmbeddingCollection.create(tables, budget_bytes=budget), "planned_mixed")


ALL = [bench_cache_overhead, bench_collection_placement]
