"""Cache-bookkeeping overhead (the paper's claim: 'cache-related operations
... introduce very little overhead'): prepare_ids cost vs the raw lookup,
transmitter cost vs buffer size, the collection-level comparison —
planner-driven mixed placement (DEVICE + per-table caches) vs the paper's
single shared arena — and the pipelined execution engine: serial fused steps
vs plan-under-compute with lookahead prefetch."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import SMOKE, Table, timeit
from repro.core import cached_embedding as ce
from repro.core import collection as col


def bench_cache_overhead(t: Table):
    vocab, dim, n_ids = (50_000, 16, 1024) if SMOKE else (1_000_000, 64, 16384)
    cfg = ce.CachedEmbeddingConfig(vocab_sizes=(vocab,), dim=dim, ids_per_step=n_ids,
                                   cache_ratio=0.05)
    st = ce.init_state(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    ids = jnp.asarray((rng.zipf(1.4, n_ids) % vocab).astype(np.int32))

    prep = jax.jit(lambda s, i: ce.prepare_ids(cfg, s, i))
    st, slots = prep(st, ids)  # warm
    sec_prep = timeit(lambda: prep(st, ids))

    gather = jax.jit(lambda s, sl: ce.gather_slots(s, sl))
    sec_gather = timeit(lambda: gather(st, slots))

    dense = jax.jit(lambda w, i: jnp.take(w, i, axis=0))
    sec_dense = timeit(lambda: dense(st.full["weight"], ids))

    t.add("cacheops/prepare_ids", sec_prep * 1e6,
          f"vs_dense_lookup={sec_prep/sec_dense:.2f}x; gather={sec_gather*1e6:.0f}us")

    for buf in (1024, 8192, 65536):
        cfg_b = ce.CachedEmbeddingConfig(vocab_sizes=(vocab,), dim=dim,
                                         ids_per_step=n_ids, cache_ratio=0.05,
                                         buffer_rows=buf)
        st_b = ce.init_state(jax.random.PRNGKey(0), cfg_b, warm=False)
        prep_b = jax.jit(lambda s, i: ce.prepare_ids(cfg_b, s, i))
        st_b, _ = prep_b(st_b, ids)
        sec_b = timeit(lambda: prep_b(st_b, ids))
        t.add(f"cacheops/buffer_rows_{buf}", sec_b * 1e6,
              f"rounds={-(-cfg_b.unique_size//buf)}")


def bench_collection_placement(t: Table):
    """Mixed placement vs single arena: DEVICE tables skip Algorithm 1
    entirely, so the prepare+gather path gets cheaper as the planner promotes
    more tables — the planner's whole value proposition, measured."""
    dim, batch = (16, 1024) if SMOKE else (64, 16384)
    vocabs = (
        {"huge": 50_000, "mid": 10_000, "small": 2_000, "tiny": 512}
        if SMOKE
        else {"huge": 1_000_000, "mid": 100_000, "small": 20_000, "tiny": 4_096}
    )
    tables = [
        col.TableConfig(n, v, dim, ids_per_step=batch, cache_ratio=0.05)
        for n, v in vocabs.items()
    ]
    rng = np.random.default_rng(0)
    fb = col.FeatureBatch(ids={
        n: jnp.asarray((rng.zipf(1.4, batch) % v).astype(np.int32))
        for n, v in vocabs.items()
    })

    def run(coll, tag):
        state = coll.init(jax.random.PRNGKey(0))

        def step(state, fb):
            state, addr = coll.prepare(state, fb)
            rows = coll.gather(coll.weights(state), addr, fb)
            return state, rows

        stepj = jax.jit(step)
        state, _ = stepj(state, fb)  # warm
        sec = timeit(lambda: stepj(state, fb))
        dev = coll.device_bytes()["device_total"]
        t.add(f"cacheops/collection_{tag}", sec * 1e6,
              f"device_bytes={dev/1e6:.1f}MB plan={coll.plan.summary()}")

    run(col.EmbeddingCollection.create(tables, cache_ratio=0.05), "single_arena")
    budget = int(4e6) if SMOKE else int(120e6)  # promotes small+tiny+mid, caches huge
    run(col.EmbeddingCollection.create(tables, budget_bytes=budget), "planned_mixed")


def bench_pipeline(t: Table):
    """Pipelined execution engine vs the serial fused step: steady-state step
    wall time on a cached DLRM.  The pipelined path runs groups of ``depth``
    steps off ONE merged cache plan (bookkeeping amortized k-fold) and
    dispatches the next group's plan before blocking on any of this group's
    losses, so the prepare stage leaves the loss-to-loss critical path.  Both
    paths are loss-bit-identical (tested property) — only the schedule
    differs.  Both paths donate the state so neither pays output copies."""
    from repro.data import synth
    from repro.models.dlrm import DLRM, DLRMConfig

    if SMOKE:
        vocabs, batch, steps = (20_000, 5_000), 128, 8
    else:
        vocabs, batch, steps = (500_000, 200_000, 100_000, 50_000), 4096, 12
    cfg = DLRMConfig(
        vocab_sizes=vocabs, embed_dim=32, batch_size=batch, cache_ratio=0.05,
        lr=0.1, bottom_mlp=(64, 32), top_mlp=(64,),
    )
    spec = synth.ZipfSparseSpec(vocab_sizes=vocabs, n_dense=13)
    batches = [
        {k: jnp.asarray(v) for k, v in synth.sparse_batch(spec, batch, 0, s).items()}
        for s in range(steps + 5)
    ]

    def steady(times):
        times.sort()
        return times[len(times) // 2]

    # -- serial oracle: one fused jitted step, block on loss each iteration --
    model = DLRM(cfg)
    state = model.init(jax.random.PRNGKey(0))
    step_j = jax.jit(model.train_step, donate_argnums=0)
    state, m = step_j(state, batches[0])  # compile + warm
    float(jax.device_get(m["loss"]))
    times = []
    for s in range(1, steps + 1):
        t0 = time.perf_counter()
        state, m = step_j(state, batches[s])
        float(jax.device_get(m["loss"]))
        times.append(time.perf_counter() - t0)
    sec_serial = steady(times)

    # -- pipelined groups: one merged plan per `depth` steps, dispatched
    #    under the previous group's first compute (the trainer's schedule) ---
    def run_pipelined(depth):
        model2 = DLRM(cfg)
        state = model2.init(jax.random.PRNGKey(0))
        plan_j = jax.jit(model2.plan_step)
        compute_j = jax.jit(model2.compute_step, donate_argnums=0)
        apply_j = jax.jit(model2.apply_step, donate_argnums=0)

        def window(s):
            return batches[s], tuple(batches[s + 1 : s + depth])

        def checked_addrs(plan):
            # the trainer's future_unresident guard: a dropped lookahead lane
            # would silently gather zeros and benchmark an inexact run
            assert int(jax.device_get(plan.future_unresident)) == 0, (
                "lookahead window exceeds cache capacity: raise cache_ratio "
                "or lower the group depth"
            )
            return (plan.addresses,) + tuple(plan.future_addresses)

        # prologue group (also compiles all three stages)
        b0, w0 = window(0)
        plan = plan_j(state, b0, w0)
        addrs = checked_addrs(plan)
        state = apply_j(state, plan)
        times = []
        s = 0
        while s + depth <= steps + 1:
            nxt = None
            for j in range(depth):
                t0 = time.perf_counter()
                if j == 0:
                    nb, nw = window(s + depth)
                    nxt = plan_j(state, nb, nw)
                state, m = compute_j(state, batches[s + j], addrs[j])
                if j == depth - 1:
                    state = apply_j(state, nxt)
                float(jax.device_get(m["loss"]))
                if s > 0:  # skip the compile group
                    times.append(time.perf_counter() - t0)
            # checked at the group boundary — the group's losses are already
            # blocked on, so this sync is off the measured critical path
            addrs = checked_addrs(nxt)
            s += depth
        return steady(times)

    t.add("cacheops/step_serial", sec_serial * 1e6, f"batch={batch} steps={steps}")
    for depth in (1, 2, 4):
        sec_pipe = run_pipelined(depth)
        t.add(f"cacheops/step_pipelined_d{depth}", sec_pipe * 1e6,
              f"group={depth} speedup={sec_serial / max(sec_pipe, 1e-12):.2f}x")


def bench_host_store(t: Table):
    """Mixed-precision host store: steady-state step time and host<->device
    bytes/step for fp32 vs fp16 vs int8 host tiers on a cached DLRM.

    The cache bookkeeping is value-independent, so all three codecs see the
    IDENTICAL miss/eviction trace — the bytes/step ratio is purely the
    encoded row size (weights cross the link encoded), which is the store's
    whole claim: >= 2x less wire traffic for int8 at zero bookkeeping cost.
    """
    from repro.data import synth
    from repro.models.dlrm import DLRM, DLRMConfig

    if SMOKE:
        vocabs, batch, steps = (20_000, 5_000), 128, 6
    else:
        vocabs, batch, steps = (500_000, 200_000, 100_000, 50_000), 4096, 12
    spec = synth.ZipfSparseSpec(vocab_sizes=vocabs, n_dense=13)
    batches = [
        {k: jnp.asarray(v) for k, v in synth.sparse_batch(spec, batch, 0, s).items()}
        for s in range(steps + 1)
    ]

    def steady(times):
        times.sort()
        return times[len(times) // 2]

    base = None
    for codec in ("fp32", "fp16", "int8"):
        cfg = DLRMConfig(
            vocab_sizes=vocabs, embed_dim=32, batch_size=batch, cache_ratio=0.05,
            lr=0.1, bottom_mlp=(64, 32), top_mlp=(64,), host_precision=codec,
        )
        model = DLRM(cfg)
        state = model.init(jax.random.PRNGKey(0))
        step_j = jax.jit(model.train_step, donate_argnums=0)
        state, m = step_j(state, batches[0])  # compile + warm
        wire0 = float(jax.device_get(m["host_wire_bytes"]))
        times = []
        for s in range(1, steps + 1):
            t0 = time.perf_counter()
            state, m = step_j(state, batches[s])
            float(jax.device_get(m["loss"]))
            times.append(time.perf_counter() - t0)
        wire = float(jax.device_get(m["host_wire_bytes"]))
        per_step = (wire - wire0) / steps
        if codec == "fp32":
            base = per_step
        sec = steady(times)
        t.add(
            f"cacheops/host_store_{codec}", sec * 1e6,
            f"wire_bytes_per_step={per_step/1e6:.3f}MB "
            f"reduction_vs_fp32={base / max(per_step, 1e-9):.2f}x "
            f"loss={float(jax.device_get(m['loss'])):.4f}",
        )


def bench_arena_precision(t: Table):
    """Mixed-precision device arena at EQUAL device-byte budget: the budget
    that holds C fp32 rows holds ~1.7x (fp16 tail) / ~2.8x (int8 tail, dim
    64) encoded rows, so at a fixed HBM spend the tiered arena keeps more of
    the zipf tail resident — measured as hit rate + training loss on a cached
    DLRM whose cache_ratio is re-solved per codec from the same byte budget.
    """
    from repro.data import synth
    from repro.models.dlrm import DLRM, DLRMConfig
    from repro.store import tiered_arena_bytes

    if SMOKE:
        vocabs, batch, steps, dim = (20_000,), 128, 6, 16
    else:
        vocabs, batch, steps, dim = (500_000,), 4096, 12, 64
    head_ratio = 0.1
    vocab = vocabs[0]
    base_cap = int(0.02 * vocab)  # the fp32 arena the budget is sized for
    budget = base_cap * dim * 4

    def rows_for_budget(codec):
        if codec == "fp32":
            return base_cap

        def bytes_at(c):
            head = min(c, max(1, int(round(head_ratio * c))))
            return tiered_arena_bytes(c, head, dim, jnp.float32, codec)

        c = base_cap
        while bytes_at(c + 1) <= budget and c < vocab:
            c += 1
        return c

    spec = synth.ZipfSparseSpec(vocab_sizes=vocabs, n_dense=13)
    batches = [
        {k: jnp.asarray(v) for k, v in synth.sparse_batch(spec, batch, 0, s).items()}
        for s in range(steps + 1)
    ]

    def steady(times):
        times.sort()
        return times[len(times) // 2]

    base_hit = None
    for codec in ("fp32", "fp16", "int8"):
        cap = rows_for_budget(codec)
        cfg = DLRMConfig(
            vocab_sizes=vocabs, embed_dim=dim, batch_size=batch,
            cache_ratio=cap / vocab, lr=0.1, bottom_mlp=(64, dim),
            top_mlp=(64,), arena_precision=codec, arena_head_ratio=head_ratio,
        )
        model = DLRM(cfg)
        state = model.init(jax.random.PRNGKey(0))
        step_j = jax.jit(model.train_step, donate_argnums=0)
        state, m = step_j(state, batches[0])  # compile + warm
        float(jax.device_get(m["loss"]))
        times = []
        for s in range(1, steps + 1):
            t0 = time.perf_counter()
            state, m = step_j(state, batches[s])
            float(jax.device_get(m["loss"]))
            times.append(time.perf_counter() - t0)
        mm = model.collection.metrics(state["emb"])
        hit = float(jax.device_get(mm["hit_rate"]))
        if codec == "fp32":
            base_hit = hit
        arena_mb = model.collection.device_bytes()
        t.add(
            f"cacheops/arena_precision_{codec}", steady(times) * 1e6,
            f"resident_rows={cap} ({cap / base_cap:.2f}x) "
            f"hit_rate={hit:.4f} (+{(hit - base_hit) * 100:.2f}pp) "
            f"loss={float(jax.device_get(m['loss'])):.4f} "
            f"arena_budget={budget / 1e6:.2f}MB "
            f"arena_saved={arena_mb['arena_bytes_saved'] / 1e6:.2f}MB",
        )


def bench_obs_overhead(t: Table):
    """Observability guardrail: the full obs stack — span tracing, the
    per-step JSONL record, the exact-counter hub reconstruction, and the
    step-time histogram — must cost < 2% of steady-state step time.  Both
    arms run the REAL Trainer loop over precomputed batches (identical
    schedule; only the obs wiring differs), so the delta isolates exactly
    what `--obs-dir` adds per step."""
    import tempfile

    from repro.data import synth
    from repro.models.dlrm import DLRM, DLRMConfig
    from repro.train.trainer import Trainer, TrainerConfig

    if SMOKE:
        vocabs, batch, steps = (20_000, 5_000), 128, 8
    else:
        vocabs, batch, steps = (500_000, 200_000, 100_000, 50_000), 4096, 12
    cfg = DLRMConfig(
        vocab_sizes=vocabs, embed_dim=32, batch_size=batch, cache_ratio=0.05,
        lr=0.1, bottom_mlp=(64, 32), top_mlp=(64,),
    )
    spec = synth.ZipfSparseSpec(vocab_sizes=vocabs, n_dense=13)
    batches = [
        {k: jnp.asarray(v) for k, v in synth.sparse_batch(spec, batch, 0, s).items()}
        for s in range(steps)
    ]

    def steady(times):
        times.sort()
        return times[len(times) // 2]

    def run(obs_dir):
        model = DLRM(cfg)
        tr = Trainer(
            TrainerConfig(max_steps=steps, obs_dir=obs_dir),
            init_fn=lambda: model.init(jax.random.PRNGKey(0)),
            step_fn=jax.jit(model.train_step, donate_argnums=0),
            # modulo: the Prefetcher reads ahead past the final step
            make_batch=lambda s: batches[s % steps],
        )
        tr.run()
        # steady-state median over post-compile steps, from the trainer's
        # own per-step wall clock (the same dt both arms record)
        return steady([r["time_s"] for r in tr.history[1:]])

    sec_off = run(None)
    with tempfile.TemporaryDirectory() as d:
        sec_on = run(d)
    overhead = sec_on / max(sec_off, 1e-12) - 1.0
    t.add("cacheops/obs_off", sec_off * 1e6, f"batch={batch} steps={steps}")
    t.add("cacheops/obs_on", sec_on * 1e6,
          f"overhead={overhead * 100:+.2f}% (guardrail < 2%)")


def bench_pallas_plan(t: Table):
    """Pallas cache hot path vs the oracle route: per-stage (plan / apply /
    gather) wall time on one cached table at the paper's serving batch.

    Both arms run the SAME bit-identical bookkeeping (tested property) — the
    fused arm only swaps the full-capacity ``argsort`` for the bounded top-K
    reducer and the two-sort dedup for the fused plan image.  Arms are
    interleaved per iteration so allocator/clock drift cancels; apply is
    donated (an undonated apply measures output copies, not the plan)."""
    from repro.core import cache as cache_lib
    from repro.obs.tracing import Tracer

    if SMOKE:
        vocab, dim, n_ids, cap, buf = 50_000, 16, 1024, 4096, 2048
    else:
        vocab, dim, n_ids, cap, buf = 1_000_000, 64, 4096, 50_000, 8192
    rng = np.random.default_rng(0)

    arms = {}
    for tag, plan_kw in (("oracle", {}), ("fused", {"use_pallas_plan": True})):
        cfg = cache_lib.CacheConfig(vocab=vocab, capacity=cap,
                                    ids_per_step=n_ids, buffer_rows=buf,
                                    **plan_kw)
        st = cache_lib.init_cache(cfg, {"w": jnp.zeros((dim,), jnp.float32)})
        full = {"w": jnp.asarray(rng.normal(size=(vocab, dim)), jnp.float32)}
        ids = jnp.asarray((rng.zipf(1.4, n_ids) % vocab).astype(np.int32))
        plan_j = jax.jit(lambda s, i, c=cfg: cache_lib.plan_prepare(c, s, i))
        apply_j = jax.jit(lambda f, s, p, c=cfg: cache_lib.apply_plan(c, f, s, p),
                          donate_argnums=(0, 1))
        # default fp32 cache: cached_rows is the raw slot-major dict
        gather_j = jax.jit(lambda s, sl: {
            k: jnp.take(v, sl, axis=0, mode="fill", fill_value=0)
            for k, v in s.cached_rows.items()
        })
        p = jax.block_until_ready(plan_j(st, ids))  # compile + warm
        full, st = jax.block_until_ready(apply_j(full, st, p))
        jax.block_until_ready(gather_j(st, p.slots))
        arms[tag] = [st, full, ids, plan_j, apply_j, gather_j, Tracer()]

    iters = 3 if SMOKE else 9
    for _ in range(iters):
        for arm in arms.values():
            st, full, ids, plan_j, apply_j, gather_j, tr = arm
            with tr.span("plan"):
                p = jax.block_until_ready(plan_j(st, ids))
            with tr.span("apply"):
                full, st = jax.block_until_ready(apply_j(full, st, p))
            with tr.span("gather"):
                jax.block_until_ready(gather_j(st, p.slots))
            arm[0], arm[1] = st, full

    total = {}
    for tag, arm in arms.items():
        stages = arm[6].stage_summary()
        pl, ap, ga = (stages[n]["mean_ms"] for n in ("plan", "apply", "gather"))
        total[tag] = pl + ap
        t.add(f"cacheops/pallas_plan_{tag}", (pl + ap + ga) * 1e3,
              f"plan={pl:.2f}ms apply={ap:.2f}ms gather={ga:.2f}ms "
              f"batch={n_ids} capacity={cap}")
    speedup = total["oracle"] / max(total["fused"], 1e-9)
    t.add("cacheops/pallas_plan_speedup", speedup,
          f"plan+apply oracle/fused at batch={n_ids} (target >= 1.5x)")


def bench_arena_decode(t: Table):
    """Guardrail: the fused gather+decode keeps the int8 tiered arena's read
    path within 1.5x of the raw fp32 gather (it is usually FASTER — the int8
    tail moves 4x fewer bytes, and the decode fuses into the same pass).
    Asserted in the CI smoke set so a decode-path regression fails the build
    rather than drifting."""
    from repro.store.arena import ArenaStore

    if SMOKE:
        cap, dim, n_ids = 4096, 16, 1024
    else:
        cap, dim, n_ids = 50_000, 64, 4096
    rng = np.random.default_rng(0)
    full = {"w": jnp.asarray(rng.normal(size=(cap, dim)), jnp.float32)}
    slots = jnp.asarray(rng.integers(0, cap, size=n_ids), jnp.int32)
    head = max(1, cap // 4)

    sec = {}
    # fp32 arm: the pre-tiering layout is a raw dict (ArenaStore refuses
    # fp32 by design) — time the plain slot gather it would run
    g_raw = jax.jit(lambda w, sl: jnp.take(w, sl, axis=0, mode="fill",
                                           fill_value=0))
    sec["fp32"] = timeit(lambda: g_raw(full["w"], slots))
    ar = ArenaStore.create(dict(full), head, "int8")
    g = jax.jit(lambda a, sl: a.gather_slots(sl))
    sec["int8"] = timeit(lambda: g(ar, slots))
    ratio = sec["int8"] / max(sec["fp32"], 1e-12)
    t.add("cacheops/arena_decode_int8_vs_fp32", sec["int8"] * 1e6,
          f"fp32={sec['fp32']*1e6:.0f}us ratio={ratio:.2f}x (guardrail < 1.5x)")
    if SMOKE:
        assert ratio < 1.5, f"int8 arena gather ratio {ratio:.2f}x >= 1.5x"


ALL = [bench_cache_overhead, bench_collection_placement, bench_pipeline,
       bench_host_store, bench_arena_precision, bench_obs_overhead,
       bench_pallas_plan, bench_arena_decode]
