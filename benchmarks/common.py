"""Benchmark utilities: timing + the A100/PCIe performance model used to
project CPU-host measurements onto the paper's testbed numbers."""
from __future__ import annotations

import os
import time
from typing import Callable

import jax

# BENCH_SMOKE=1 shrinks every benchmark to CI-sized shapes (seconds, not
# minutes) so the entrypoints can't silently rot — numbers are meaningless
# but every code path still runs.
SMOKE = os.environ.get("BENCH_SMOKE", "") == "1"

# paper testbed (Table 2) + TPU-target constants
PCIE3_BW = 16e9  # bytes/s, PCIe 3.0 x16 (paper's GPU interconnect)
A100_HBM_BW = 2.0e12  # bytes/s
DDR4_BW = 3.2e10  # bytes/s per socket (EPYC 7543, 8ch DDR4-3200)
TPU_HOST_LINK = 100e9  # bytes/s host DMA (v5e host)
TPU_HBM_BW = 819e9


def timeit(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall seconds per call (device-synchronized)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


class Table:
    """Collects (name, us_per_call, derived) rows and prints the CSV."""

    def __init__(self):
        self.rows = []

    def add(self, name: str, us_per_call: float, derived: str):
        self.rows.append((name, us_per_call, derived))
        print(f"{name},{us_per_call:.1f},{derived}", flush=True)

    def extend(self, other: "Table"):
        self.rows.extend(other.rows)
