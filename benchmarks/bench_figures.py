"""One benchmark per paper table/figure (see DESIGN.md §7 index).

All run on the CPU host; where the paper reports GPU-testbed absolutes we
report (a) our measured numbers and (b) the bandwidth-model projection onto
the paper's hardware, clearly labelled `derived`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import A100_HBM_BW, DDR4_BW, PCIE3_BW, Table, timeit
from repro.core import cached_embedding as ce
from repro.core import freq
from repro.core.policies import Policy
from repro.data import synth
from repro.models.dlrm import DLRM, DLRMConfig
from repro.nn.embedding_bag import embedding_bag


# --------------------------------------------------------------------------
# Fig. 1 — EmbeddingBag throughput (device vs host is a bandwidth statement)
# --------------------------------------------------------------------------


def fig1_embedding_bag(t: Table):
    vocab, dim = 200_000, 128
    table = jnp.asarray(np.random.default_rng(0).normal(size=(vocab, dim)).astype(np.float32))
    for batch in (1024, 8192, 65536):
        n = batch * 26
        ids = jnp.asarray(np.random.default_rng(1).integers(0, vocab, n).astype(np.int32))
        seg = jnp.asarray(np.repeat(np.arange(batch * 26 // 26 * 26 // 26), 26)[:n].astype(np.int32))
        seg = jnp.asarray(np.arange(n, dtype=np.int32) // 26)
        fn = jax.jit(lambda tb, i, s: embedding_bag(tb, i, s, batch))
        sec = timeit(fn, table, ids, seg)
        bytes_moved = n * dim * 4
        eff_bw = bytes_moved / sec
        # the paper's Fig-1 ratio: HBM-bound GPU vs DRAM-bound CPU
        proj_speedup = A100_HBM_BW / DDR4_BW
        t.add(
            f"fig1/embedding_bag_b{batch}",
            sec * 1e6,
            f"eff_bw={eff_bw/1e9:.1f}GB/s; A100-vs-CPU model speedup={proj_speedup:.0f}x",
        )


# --------------------------------------------------------------------------
# Fig. 2 — id frequency skew of the synthetic datasets
# --------------------------------------------------------------------------


def fig2_freq_skew(t: Table):
    for name, vocab, a in (("criteo-like", 1_000_000, 1.2), ("avazu-like", 300_000, 1.3)):
        spec = synth.ZipfSparseSpec(vocab_sizes=(vocab,), zipf_a=a)
        counts = freq.collect_counts(synth.count_stream(spec, 8192, 12, seed=0), vocab)
        cov = freq.coverage(counts, [0.0014, 0.00012, 0.1])
        t.add(
            f"fig2/skew_{name}",
            0.0,
            f"top0.14%={cov[0.0014]:.2f}; top0.012%={cov[0.00012]:.2f}; top10%={cov[0.1]:.2f}",
        )


# --------------------------------------------------------------------------
# Figs. 5/6 — AUROC vs cache ratio (accuracy parity)
# --------------------------------------------------------------------------


def _train_auc(cache_ratio: float, steps: int = 20, seed: int = 0):
    cfg = DLRMConfig(vocab_sizes=(4096, 2048, 1024), embed_dim=16, batch_size=256,
                     cache_ratio=cache_ratio, lr=0.5, bottom_mlp=(64, 16), top_mlp=(64,))
    model = DLRM(cfg)
    state = model.init(jax.random.PRNGKey(seed))
    spec = synth.ZipfSparseSpec(vocab_sizes=cfg.vocab_sizes, n_dense=13)
    step = jax.jit(model.train_step)
    auc = loss = 0.0
    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in synth.sparse_batch(spec, 256, seed, i).items()}
        state, m = step(state, batch)
        auc, loss = float(m["auc"]), float(m["loss"])
    return auc, loss, float(m["hit_rate"])


def fig56_accuracy_vs_ratio(t: Table):
    base_auc, base_loss, _ = _train_auc(1.0)
    for ratio in (0.015, 0.05, 0.25):
        auc, loss, hit = _train_auc(ratio)
        t.add(
            f"fig5/auroc_ratio_{ratio}",
            0.0,
            f"auc={auc:.4f}; delta_vs_uncached={abs(auc-base_auc):.5f}; hit_rate={hit:.3f}",
        )


# --------------------------------------------------------------------------
# Figs. 7/8 — device memory vs cache ratio (paper config accounting)
# --------------------------------------------------------------------------


def fig78_memory(t: Table):
    from repro.configs.shapes import AVAZU_VOCABS, CRITEO_VOCABS

    for name, vocabs, batch in (("criteo", CRITEO_VOCABS, 16384), ("avazu", AVAZU_VOCABS, 65536)):
        full_gb = sum(vocabs) * 128 * 4 / 1e9
        for ratio in (0.015, 0.05, 0.1, 1.0):
            cfg = ce.CachedEmbeddingConfig(
                vocab_sizes=tuple(vocabs), dim=128,
                ids_per_step=batch * len(vocabs), cache_ratio=ratio,
                max_unique_per_step=1 << 19,
            )
            b = ce.device_bytes(cfg)
            fast_gb = b["fast_tier_bytes"] / 1e9
            t.add(
                f"fig7/mem_{name}_ratio{ratio}",
                0.0,
                f"fast_tier={fast_gb:.2f}GB; full_table={full_gb:.2f}GB; saving={100*(1-fast_gb/full_gb):.0f}%",
            )


# --------------------------------------------------------------------------
# Figs. 9/10 — throughput vs cache ratio (measured step + modeled transfer)
# --------------------------------------------------------------------------


def fig910_throughput(t: Table):
    batch = 1024
    for ratio in (0.015, 0.1, 0.5):
        cfg = DLRMConfig(vocab_sizes=(65536, 32768, 16384), embed_dim=32, batch_size=batch,
                         cache_ratio=ratio, lr=0.5, bottom_mlp=(64, 32), top_mlp=(64,))
        model = DLRM(cfg)
        state = model.init(jax.random.PRNGKey(0))
        spec = synth.ZipfSparseSpec(vocab_sizes=cfg.vocab_sizes, n_dense=13)
        step = jax.jit(model.train_step)
        bt = {k: jnp.asarray(v) for k, v in synth.sparse_batch(spec, batch, 0, 0).items()}
        state, m = step(state, bt)  # warm compile + warm cache
        # measure steady-state steps (fresh zipf batch each time is host-side)
        batches = [
            {k: jnp.asarray(v) for k, v in synth.sparse_batch(spec, batch, 0, i).items()}
            for i in range(1, 5)
        ]
        import time as _time

        t0 = _time.perf_counter()
        for bt_i in batches:
            state, m = step(state, bt_i)
        jax.block_until_ready(state["emb"].cache.cached_rows["weight"])
        sec = (_time.perf_counter() - t0) / len(batches)
        hit = float(state["emb"].cache.hit_rate())
        # paper-testbed projection: PCIe transfer of missed rows dominates
        miss_rows = batch * 3 * (1 - hit)
        pcie_s = miss_rows * 128 * 4 * 2 / PCIE3_BW  # in + evict out, dim-128 rows
        t.add(
            f"fig9/throughput_ratio{ratio}",
            sec * 1e6,
            f"samples_per_s={batch/sec:.0f}; hit_rate={hit:.3f}; modeled_pcie_ms={pcie_s*1e3:.2f}",
        )


# --------------------------------------------------------------------------
# beyond-paper: eviction-policy ablation (hit rate at fixed ratio)
# --------------------------------------------------------------------------


def policy_ablation(t: Table):
    for pol in (Policy.FREQ_LFU, Policy.LRU, Policy.RUNTIME_LFU, Policy.UVM_ROW):
        cfg = ce.CachedEmbeddingConfig(
            vocab_sizes=(100_000,), dim=16, ids_per_step=4096,
            cache_ratio=0.05, policy=pol,
        )
        st = ce.init_state(jax.random.PRNGKey(0), cfg,
                           counts=_zipf_counts(100_000))
        rng = np.random.default_rng(0)
        step = jax.jit(lambda s, i: ce.prepare_ids(cfg, s, i))
        for i in range(12):
            ids = _zipf_ids(rng, 100_000, 4096)
            st, _ = step(st, jnp.asarray(ids))
        t.add(f"ablation/policy_{pol.value}", 0.0, f"hit_rate={float(st.cache.hit_rate()):.4f}")


def _zipf_counts(vocab):
    rng = np.random.default_rng(42)
    return np.bincount(_zipf_ids(rng, vocab, 200_000), minlength=vocab)


def _zipf_ids(rng, vocab, n):
    from repro.data.synth import _zipf_ids as z

    # raw ids ARE popularity-ranked in the synthetic stream; shuffle the id
    # space with a fixed permutation so the freq module has real work to do.
    ids = z(rng, vocab, n, 1.2)
    perm = np.random.default_rng(7).permutation(vocab)
    return perm[ids].astype(np.int32)


ALL = [
    fig1_embedding_bag,
    fig2_freq_skew,
    fig56_accuracy_vs_ratio,
    fig78_memory,
    fig910_throughput,
    policy_ablation,
]
